//! Amortized Bayesian inference & uncertainty quantification over
//! conditional flows — the paper's headline workload (seismic imaging,
//! medical imaging, CO2 monitoring all use InvertibleNetworks.jl as an
//! amortized posterior sampler).
//!
//! The pipeline:
//!
//! 1. [`simulator`] — a catalog of synthetic inverse problems generating
//!    (x, y) training pairs on the fly: denoising, deconvolution,
//!    inpainting over textured-blob fields, plus the analytically
//!    solvable [`crate::data::LinearGaussian`] oracle;
//! 2. [`trainer`] — [`trainer::amortized_train`] streams simulator
//!    minibatches through the existing (data-parallel) train path, with a
//!    held-out eval split feeding the `eval_nll` model-selection signal;
//! 3. [`analysis`] — posterior sampling for a given observation y,
//!    pointwise mean/std uncertainty maps, quantile intervals, and the
//!    calibration diagnostics (SBC rank uniformity, credible-interval
//!    coverage), validated exactly against the closed-form
//!    linear-Gaussian posterior.
//!
//! CLI: `invertnet posterior-train | posterior-sample | calibrate`; the
//! serve protocol's `posterior` op answers "samples + mean/std map for
//! this y" through the micro-batcher, bit-identical to the in-process
//! [`analysis::posterior_samples`] + [`analysis::summarize`] path.

pub mod analysis;
pub mod simulator;
pub mod trainer;

pub use analysis::{calibrate, posterior_samples, summarize, Calibration,
                   PosteriorSummary};
pub use simulator::Simulator;
pub use trainer::{amortized_train, PosteriorTrainConfig};
