//! Per-entry profiler: times every (layer, entry) program of a network
//! individually — the L3 profiling tool for the performance pass
//! (EXPERIMENTS.md §Perf). `invertnet profile --net NAME [--backend xla]`.
//!
//! Backend-agnostic: operands are synthesized from the layer metadata
//! (entry convention: see `backend` module docs), so the same table works
//! for the RefBackend and the PJRT runtime.
//!
//! Each iteration is timed individually into a
//! [`telemetry::Histogram`](crate::telemetry::Histogram), so the report
//! carries percentiles, not just means. Two output modes:
//!
//! * default — the human table (count-weighted totals per entry);
//! * `--json` — an `invertnet-profile/v1` document for tooling, with
//!   per-(signature, entry) count/mean/p50/p99 in microseconds.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::Result;

use crate::api::Engine;
use crate::flow::StepKind;
use crate::telemetry::{HistSnapshot, Histogram};
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::rng::Pcg64;

/// Schema tag of the `--json` report.
pub const SCHEMA: &str = "invertnet-profile/v1";

const ENTRIES: [&str; 4] = ["forward", "inverse", "backward", "backward_stored"];

fn rand_t(shape: &[usize], rng: &mut Pcg64) -> Tensor {
    Tensor { shape: shape.to_vec(), data: rng.normal_vec(shape.iter().product()) }
}

/// Timings for one distinct layer signature: how many steps use it, and
/// one per-iteration latency histogram per entry point.
pub struct SigProfile {
    pub sig: String,
    pub count: usize,
    /// Indexed like [`ENTRIES`]: forward, inverse, backward, backward_stored.
    pub timings: [HistSnapshot; 4],
}

/// Run the measurement: every distinct (sig, entry) of `net`, one warmup
/// call (compiling backends build their executable there) plus `iters`
/// individually-timed calls each.
pub fn measure(engine: &Engine, net: &str, iters: usize)
               -> Result<(usize, Vec<SigProfile>)> {
    let flow = engine.flow(net)?;
    let params = flow.init_params(7)?;
    let mut rng = Pcg64::new(123);

    // count occurrences of each signature + remember one step index
    let mut sig_count: BTreeMap<String, (usize, usize)> = BTreeMap::new();
    for (i, step) in flow.def.steps.iter().enumerate() {
        if step.kind == StepKind::Layer {
            let e = sig_count.entry(step.sig.clone()).or_insert((0, i));
            e.0 += 1;
        }
    }

    let mut out = Vec::with_capacity(sig_count.len());
    for (sig, (count, step_idx)) in &sig_count {
        let meta = engine.manifest().layer(sig)?;
        let n = meta.in_shape[0];
        let cond = meta.cond_shape.as_ref().map(|s| rand_t(s, &mut rng));
        let step_params = &params.tensors[*step_idx];
        let mut timings: Vec<HistSnapshot> = Vec::with_capacity(ENTRIES.len());
        for entry in ENTRIES {
            // operands per the shared entry convention
            let acts: Vec<Tensor> = match entry {
                "forward" => vec![rand_t(&meta.in_shape, &mut rng)],
                "inverse" => vec![rand_t(&meta.out_shape, &mut rng)],
                "backward" => vec![rand_t(&meta.out_shape, &mut rng),
                                   rand_t(&[n], &mut rng),
                                   rand_t(&meta.out_shape, &mut rng)],
                _ => vec![rand_t(&meta.out_shape, &mut rng),
                          rand_t(&[n], &mut rng),
                          rand_t(&meta.in_shape, &mut rng)],
            };
            let act_refs: Vec<&Tensor> = acts.iter().collect();
            engine.backend().execute_layer(
                meta, entry, &act_refs, cond.as_ref(), step_params)?;
            let hist = Histogram::new();
            for _ in 0..iters {
                let t0 = Instant::now();
                engine.backend().execute_layer(
                    meta, entry, &act_refs, cond.as_ref(), step_params)?;
                hist.record(t0.elapsed().as_micros() as u64);
            }
            timings.push(hist.snapshot());
        }
        let timings: [HistSnapshot; 4] = timings.try_into()
            .unwrap_or_else(|_| unreachable!("{} entries", ENTRIES.len()));
        out.push(SigProfile { sig: sig.clone(), count: *count, timings });
    }
    Ok((flow.def.steps.len(), out))
}

/// Time every distinct (sig, entry) of `net`, `iters` times each, and print
/// a table sorted by signature with count-weighted totals.
pub fn profile_network(engine: &Engine, net: &str, iters: usize) -> Result<()> {
    let (steps, profiles) = measure(engine, net, iters)?;
    println!("# per-entry mean latency, network {net} ({steps} steps, x{iters} iters, \
              backend {})",
             engine.backend_name());
    println!("{:<44} {:>5} {:>12} {:>12} {:>12} {:>12}",
             "signature", "count", "forward", "inverse", "backward", "bwd_stored");
    let mut totals = [0.0f64; 4];
    for p in &profiles {
        let row: Vec<f64> =
            p.timings.iter().map(|h| h.mean() / 1e3).collect();
        for (t, r) in totals.iter_mut().zip(&row) {
            *t += r * p.count as f64;
        }
        println!("{:<44} {:>5} {:>9.3} ms {:>9.3} ms {:>9.3} ms {:>9.3} ms",
                 p.sig, p.count, row[0], row[1], row[2], row[3]);
    }
    println!("{:<44} {:>5} {:>9.3} ms {:>9.3} ms {:>9.3} ms {:>9.3} ms",
             "TOTAL (weighted by count)", "-",
             totals[0], totals[1], totals[2], totals[3]);
    println!("# invertible step ~= fwd + bwd totals; stored step ~= fwd + bwd_stored");
    Ok(())
}

/// The machine-readable report (`invertnet profile --json`):
/// per-(signature, entry) histogram-derived stats in microseconds, plus
/// count-weighted per-entry totals.
pub fn profile_network_json(engine: &Engine, net: &str, iters: usize)
                            -> Result<Json> {
    let (steps, profiles) = measure(engine, net, iters)?;
    let hist_json = |h: &HistSnapshot| {
        Json::obj(vec![
            ("count", Json::Num(h.count as f64)),
            ("sum_us", Json::Num(h.sum as f64)),
            ("mean_us", Json::Num(h.mean())),
            ("p50_us", Json::Num(h.quantile(0.50))),
            ("p99_us", Json::Num(h.quantile(0.99))),
        ])
    };
    let mut totals = [0.0f64; 4];
    let entries = Json::Arr(profiles.iter().map(|p| {
        let timings = Json::obj(
            ENTRIES.iter().zip(&p.timings).map(|(e, h)| {
                (*e, hist_json(h))
            }).collect());
        for (t, h) in totals.iter_mut().zip(&p.timings) {
            *t += h.mean() * p.count as f64;
        }
        Json::obj(vec![
            ("signature", Json::Str(p.sig.clone())),
            ("count", Json::Num(p.count as f64)),
            ("timings", timings),
        ])
    }).collect());
    Ok(Json::obj(vec![
        ("schema", Json::Str(SCHEMA.into())),
        ("network", Json::Str(net.into())),
        ("backend", Json::Str(engine.backend_name().into())),
        ("steps", Json::Num(steps as f64)),
        ("iters", Json::Num(iters as f64)),
        ("entries", entries),
        ("totals_us", Json::obj(
            ENTRIES.iter().zip(&totals)
                .map(|(e, t)| (*e, Json::Num(*t)))
                .collect())),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_report_carries_schema_and_per_entry_histograms() {
        let engine = Engine::native().unwrap();
        let doc = profile_network_json(&engine, "realnvp2d", 2).unwrap();
        assert_eq!(doc.req("schema").unwrap().as_str().unwrap(), SCHEMA);
        assert_eq!(doc.req("network").unwrap().as_str().unwrap(),
                   "realnvp2d");
        let entries = doc.req("entries").unwrap().as_arr().unwrap();
        assert!(!entries.is_empty());
        for e in entries {
            assert!(e.req("count").unwrap().as_usize().unwrap() > 0);
            let timings = e.req("timings").unwrap();
            for name in ENTRIES {
                let t = timings.req(name).unwrap();
                assert_eq!(t.req("count").unwrap().as_usize().unwrap(), 2,
                           "{name} must time every iteration");
                let mean = t.req("mean_us").unwrap().as_f64().unwrap();
                let p99 = t.req("p99_us").unwrap().as_f64().unwrap();
                assert!(mean >= 0.0 && p99 >= 0.0);
            }
        }
        for name in ENTRIES {
            assert!(doc.req("totals_us").unwrap().req(name).unwrap()
                        .as_f64().unwrap() >= 0.0);
        }
        // the document is valid JSON text end-to-end
        Json::parse(&doc.to_string()).unwrap();
    }
}
