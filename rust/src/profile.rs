//! Per-entry profiler: times every (layer, entry) program of a network
//! individually — the L3 profiling tool for the performance pass
//! (EXPERIMENTS.md §Perf). `invertnet profile --net NAME [--backend xla]`.
//!
//! Backend-agnostic: operands are synthesized from the layer metadata
//! (entry convention: see `backend` module docs), so the same table works
//! for the RefBackend and the PJRT runtime.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::Result;

use crate::api::Engine;
use crate::flow::StepKind;
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

fn rand_t(shape: &[usize], rng: &mut Pcg64) -> Tensor {
    Tensor { shape: shape.to_vec(), data: rng.normal_vec(shape.iter().product()) }
}

/// Time every distinct (sig, entry) of `net`, `iters` times each, and print
/// a table sorted by signature with count-weighted totals.
pub fn profile_network(engine: &Engine, net: &str, iters: usize) -> Result<()> {
    let flow = engine.flow(net)?;
    let params = flow.init_params(7)?;
    let mut rng = Pcg64::new(123);

    // count occurrences of each signature + remember one step index
    let mut sig_count: BTreeMap<String, (usize, usize)> = BTreeMap::new();
    for (i, step) in flow.def.steps.iter().enumerate() {
        if step.kind == StepKind::Layer {
            let e = sig_count.entry(step.sig.clone()).or_insert((0, i));
            e.0 += 1;
        }
    }

    println!("# per-entry mean latency, network {net} ({} steps, x{iters} iters, \
              backend {})",
             flow.def.steps.len(), engine.backend_name());
    println!("{:<44} {:>5} {:>12} {:>12} {:>12} {:>12}",
             "signature", "count", "forward", "inverse", "backward", "bwd_stored");
    let mut totals = [0.0f64; 4];
    for (sig, (count, step_idx)) in &sig_count {
        let meta = engine.manifest().layer(sig)?;
        let n = meta.in_shape[0];
        let cond = meta.cond_shape.as_ref().map(|s| rand_t(s, &mut rng));
        let step_params = &params.tensors[*step_idx];
        let mut row = [0.0f64; 4];
        for (ei, entry) in ["forward", "inverse", "backward", "backward_stored"]
            .iter().enumerate()
        {
            // operands per the shared entry convention
            let acts: Vec<Tensor> = match *entry {
                "forward" => vec![rand_t(&meta.in_shape, &mut rng)],
                "inverse" => vec![rand_t(&meta.out_shape, &mut rng)],
                "backward" => vec![rand_t(&meta.out_shape, &mut rng),
                                   rand_t(&[n], &mut rng),
                                   rand_t(&meta.out_shape, &mut rng)],
                _ => vec![rand_t(&meta.out_shape, &mut rng),
                          rand_t(&[n], &mut rng),
                          rand_t(&meta.in_shape, &mut rng)],
            };
            let act_refs: Vec<&Tensor> = acts.iter().collect();
            // warmup (compiling backends build their executable here)
            engine.backend().execute_layer(
                meta, entry, &act_refs, cond.as_ref(), step_params)?;
            let t0 = Instant::now();
            for _ in 0..iters {
                engine.backend().execute_layer(
                    meta, entry, &act_refs, cond.as_ref(), step_params)?;
            }
            row[ei] = t0.elapsed().as_secs_f64() / iters as f64;
            totals[ei] += row[ei] * *count as f64;
        }
        println!("{sig:<44} {count:>5} {:>9.3} ms {:>9.3} ms {:>9.3} ms {:>9.3} ms",
                 row[0] * 1e3, row[1] * 1e3, row[2] * 1e3, row[3] * 1e3);
    }
    println!("{:<44} {:>5} {:>9.3} ms {:>9.3} ms {:>9.3} ms {:>9.3} ms",
             "TOTAL (weighted by count)", "-",
             totals[0] * 1e3, totals[1] * 1e3, totals[2] * 1e3, totals[3] * 1e3);
    println!("# invertible step ~= fwd + bwd totals; stored step ~= fwd + bwd_stored");
    Ok(())
}
