//! Per-entry profiler: times every (layer, entry) executable of a network
//! individually — the L3 profiling tool for the performance pass
//! (EXPERIMENTS.md §Perf). `invertnet profile --net NAME`.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::FlowSession;
use crate::flow::{ParamStore, StepKind};
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;
use crate::MemoryLedger;

fn rand_t(shape: &[usize], rng: &mut Pcg64) -> Tensor {
    Tensor { shape: shape.to_vec(), data: rng.normal_vec(shape.iter().product()) }
}

/// Time every distinct (sig, entry) of `net`, `iters` times each, and print
/// a table sorted by total cost contribution (count x mean).
pub fn profile_network(rt: &Runtime, net: &str, iters: usize) -> Result<()> {
    let session = FlowSession::new(rt, net, MemoryLedger::new())?;
    let _params = ParamStore::init(&session.def, &rt.manifest, 7)?;
    let mut rng = Pcg64::new(123);

    // count occurrences of each signature + remember one step index
    let mut sig_count: BTreeMap<String, (usize, usize)> = BTreeMap::new();
    for (i, step) in session.def.steps.iter().enumerate() {
        if step.kind == StepKind::Layer {
            let e = sig_count.entry(step.sig.clone()).or_insert((0, i));
            e.0 += 1;
        }
    }

    println!("# per-entry mean latency, network {net} ({} steps, x{} iters)",
             session.def.steps.len(), iters);
    println!("{:<44} {:>5} {:>12} {:>12} {:>12} {:>12}",
             "signature", "count", "forward", "inverse", "backward", "bwd_stored");
    let mut totals = [0.0f64; 4];
    for (sig, (count, step_idx)) in &sig_count {
        let _meta = rt.manifest.layer(sig)?;
        let mut row = [0.0f64; 4];
        for (ei, entry) in ["forward", "inverse", "backward", "backward_stored"]
            .iter().enumerate()
        {
            let compiled = rt.layer_entry(sig, entry)?;
            // build random operands per manifest shapes
            let ops: Vec<Tensor> = compiled.meta.operands.iter()
                .map(|o| rand_t(&o.shape, &mut rng))
                .collect();
            let lits: Vec<xla::Literal> = ops.iter()
                .map(|t| t.to_literal()).collect::<Result<_>>()?;
            let args: Vec<&xla::Literal> = lits.iter().collect();
            compiled.execute(&args)?; // warmup (compile already done)
            let t0 = Instant::now();
            for _ in 0..iters {
                compiled.execute(&args)?;
            }
            row[ei] = t0.elapsed().as_secs_f64() / iters as f64;
            totals[ei] += row[ei] * *count as f64;
        }
        println!("{sig:<44} {count:>5} {:>9.3} ms {:>9.3} ms {:>9.3} ms {:>9.3} ms",
                 row[0] * 1e3, row[1] * 1e3, row[2] * 1e3, row[3] * 1e3);
        let _ = step_idx;
    }
    println!("{:<44} {:>5} {:>9.3} ms {:>9.3} ms {:>9.3} ms {:>9.3} ms",
             "TOTAL (weighted by count)", "-",
             totals[0] * 1e3, totals[1] * 1e3, totals[2] * 1e3, totals[3] * 1e3);
    println!("# invertible step ~= fwd + bwd totals; stored step ~= fwd + bwd_stored");
    Ok(())
}
