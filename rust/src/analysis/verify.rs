//! The static flow verifier: shape/width propagation, split/concat and
//! squeeze bookkeeping, conditional-input widths, and the invertibility
//! audit — all over manifest metadata, without resolving or executing
//! the network. Unlike [`NetworkDef::resolve`](crate::flow::NetworkDef),
//! which bails at the first problem, the verifier keeps walking and
//! collects *every* violation as a [`Diagnostic`].

use crate::runtime::manifest::parse_split;
use crate::runtime::{Manifest, NetworkMeta};

use super::{codes, Diagnostic};

/// The layer kinds with a total inverse — every kind the coordinator can
/// run backward without a stored tape. Anything else fails the
/// invertibility audit with [`codes::NO_INVERSE`].
pub const INVERTIBLE_KINDS: &[&str] = &[
    "actnorm", "addcpl", "condcpl", "conv1x1", "densecpl", "glowcpl",
    "haar", "hint", "hyper", "permute",
];

/// Statically verify one network's layer program. Returns every finding;
/// an empty vec means the definition is clean.
pub fn verify_network(manifest: &Manifest, net: &NetworkMeta)
                      -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let mut cur = net.in_shape.clone();
    let mut derived_latents: Vec<Vec<usize>> = Vec::new();
    let mut cond_consumed = false;

    for (i, sig) in net.layers.iter().enumerate() {
        if let Some((zc, in_shape)) = parse_split(sig) {
            if in_shape != cur {
                diags.push(Diagnostic::error(codes::BAD_SPLIT, Some(i),
                    format!("split marker {sig:?} expects input \
                             {in_shape:?}, flow shape here is {cur:?}")));
                cur = in_shape; // resync to the declared shape and continue
            }
            let c = *cur.last().unwrap_or(&0);
            if zc == 0 || zc >= c {
                diags.push(Diagnostic::error(codes::BAD_SPLIT, Some(i),
                    format!("split zc={zc} must leave both halves \
                             non-empty at width {c}")));
                continue; // can't derive a latent from a degenerate split
            }
            let mut z = cur.clone();
            *z.last_mut().unwrap() = zc;
            derived_latents.push(z);
            *cur.last_mut().unwrap() = c - zc;
            continue;
        }

        let Ok(meta) = manifest.layer(sig) else {
            diags.push(Diagnostic::error(codes::UNKNOWN_LAYER, Some(i),
                format!("network references undefined layer sig {sig:?}")));
            continue; // shape unknown: keep cur and keep walking
        };

        if !INVERTIBLE_KINDS.contains(&meta.kind.as_str()) {
            diags.push(Diagnostic::error(codes::NO_INVERSE, Some(i),
                format!("layer kind {:?} does not declare a total \
                         inverse", meta.kind)));
        }

        if meta.in_shape != cur {
            diags.push(Diagnostic::error(codes::SHAPE_MISMATCH, Some(i),
                format!("layer {sig} expects input {:?}, flow shape here \
                         is {cur:?}", meta.in_shape)));
        }

        // squeeze factors and width rules, judged on the layer's own
        // declared shapes (a chain mismatch is reported separately above)
        if meta.kind == "haar" {
            let s = &meta.in_shape;
            let squeezed_ok = s.len() == 4
                && s[1] % 2 == 0
                && s[2] % 2 == 0
                && meta.out_shape == vec![s[0], s[1] / 2, s[2] / 2, 4 * s[3]];
            if !squeezed_ok {
                diags.push(Diagnostic::error(codes::BAD_SQUEEZE, Some(i),
                    format!("haar squeeze {sig} must map 4-D \
                             [n, 2h, 2w, c] to [n, h, w, 4c], got {:?} -> \
                             {:?}", meta.in_shape, meta.out_shape)));
            }
        } else if meta.out_shape != meta.in_shape {
            diags.push(Diagnostic::error(codes::WIDTH_CHANGE, Some(i),
                format!("layer {sig} changes shape {:?} -> {:?}; width \
                         changes are only sanctioned at squeeze points",
                        meta.in_shape, meta.out_shape)));
        }

        match (&meta.cond_shape, &net.cond_shape) {
            (Some(lc), Some(nc)) => {
                cond_consumed = true;
                if lc != nc {
                    diags.push(Diagnostic::error(codes::COND_MISMATCH,
                        Some(i),
                        format!("layer {sig} conditions on {lc:?}, network \
                                 declares cond {nc:?}")));
                }
            }
            (Some(lc), None) => {
                cond_consumed = true;
                diags.push(Diagnostic::error(codes::COND_MISMATCH, Some(i),
                    format!("layer {sig} conditions on {lc:?}, but the \
                             network declares no conditioning input")));
            }
            (None, _) => {}
        }

        cur = meta.out_shape.clone();
    }

    derived_latents.push(cur);

    if net.cond_shape.is_some() && !cond_consumed {
        diags.push(Diagnostic::warning(codes::DANGLING_COND, None,
            format!("network declares cond {:?} but no layer consumes it",
                    net.cond_shape.as_ref().unwrap())));
    }

    if derived_latents != net.latent_shapes {
        diags.push(Diagnostic::error(codes::LATENT_MISMATCH, None,
            format!("declared latent shapes {:?} != derived {:?} (split \
                     halves + final flow shape)",
                    net.latent_shapes, derived_latents)));
    }

    // bijectivity on the stated dims: the declared latents must tile the
    // input element count exactly — no dimension created or destroyed
    let in_elems: usize = net.in_shape.iter().product();
    let latent_elems: usize = net.latent_shapes.iter()
        .map(|s| s.iter().product::<usize>())
        .sum();
    if latent_elems != in_elems {
        diags.push(Diagnostic::error(codes::NOT_BIJECTIVE, None,
            format!("latent shapes carry {latent_elems} elements but the \
                     input has {in_elems}: the composed chain is not a \
                     bijection on its stated dimensions")));
    }

    // numeric-range lints ride the same diagnostic stream: interval
    // propagation of declared scale bounds (see `analysis::numerics`)
    diags.extend(super::numerics::check_network(manifest, net));

    diags
}

/// Verify every network in a manifest. Returns `(name, diagnostics)`
/// pairs in catalog order.
pub fn verify_manifest(manifest: &Manifest)
                       -> Vec<(String, Vec<Diagnostic>)> {
    manifest.networks.values()
        .map(|net| (net.name.clone(), verify_network(manifest, net)))
        .collect()
}

/// Validate a checkpoint-every-K schedule against a network of `depth`
/// layers: `K == 0` is an error (nothing would tape, the executor clamps
/// to 1); `K > depth` a warning (degenerates to taping only layer 0).
pub fn verify_checkpoint_k(depth: usize, k: usize) -> Vec<Diagnostic> {
    if k == 0 {
        vec![Diagnostic::error(codes::BAD_CHECKPOINT_K, None,
            "checkpoint every 0 layers is meaningless (the executor \
             clamps K to 1); pass K >= 1".to_string())]
    } else if k > depth {
        vec![Diagnostic::warning(codes::BAD_CHECKPOINT_K, None,
            format!("checkpoint every {k} layers exceeds the network \
                     depth {depth}: only layer 0 tapes, the schedule \
                     degenerates to near-invertible"))]
    } else {
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::has_errors;
    use crate::runtime::builtin_manifest;

    #[test]
    fn builtin_catalog_is_clean() {
        let m = builtin_manifest().unwrap();
        assert!(!m.networks.is_empty());
        for (name, diags) in verify_manifest(&m) {
            assert!(diags.is_empty(), "{name}: {diags:?}");
        }
    }

    #[test]
    fn checkpoint_k_bounds() {
        let zero = verify_checkpoint_k(16, 0);
        assert!(has_errors(&zero));
        assert_eq!(zero[0].code, codes::BAD_CHECKPOINT_K);
        let over = verify_checkpoint_k(16, 17);
        assert!(!has_errors(&over) && !over.is_empty());
        assert!(verify_checkpoint_k(16, 4).is_empty());
        assert!(verify_checkpoint_k(16, 16).is_empty());
    }

    #[test]
    fn unknown_layer_is_reported_not_fatal() {
        let mut m = builtin_manifest().unwrap();
        m.networks.get_mut("realnvp2d").unwrap().layers[0] =
            "warp__256x2".to_string();
        let diags = verify_network(&m, m.network("realnvp2d").unwrap());
        assert!(diags.iter().any(|d| d.code == codes::UNKNOWN_LAYER),
                "{diags:?}");
    }
}
