//! Static checkpoint validation: an `index.json` is checked against the
//! network spec *before any weight bytes load* — wrong-shaped, unknown,
//! and (crucially) missing params are all structured diagnostics, so a
//! truncated or foreign checkpoint can't reach a registry or a ledger.
//!
//! This closes a real gap: `ParamStore::load` validates every entry it
//! finds but silently keeps the random init for params the index never
//! mentions. [`verify_checkpoint_index`] makes completeness explicit.

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

use anyhow::{Context, Result};

use crate::flow::{NetworkDef, StepKind};
use crate::runtime::Manifest;
use crate::util::json::Json;

use super::{codes, Diagnostic};

/// Validate a checkpoint directory's `index.json` against the resolved
/// network. IO/parse failures are `Err`; content violations come back as
/// diagnostics (empty vec = the checkpoint matches the spec exactly).
pub fn verify_checkpoint_index(manifest: &Manifest, def: &NetworkDef,
                               dir: &Path) -> Result<Vec<Diagnostic>> {
    let text = std::fs::read_to_string(dir.join("index.json"))
        .with_context(|| format!("reading checkpoint {dir:?}"))?;
    let doc = Json::parse(&text)?;

    // every param the spec expects, keyed the way the index records them
    let mut expected: BTreeMap<(usize, String), Vec<usize>> = BTreeMap::new();
    for (si, step) in def.steps.iter().enumerate() {
        if step.kind != StepKind::Layer {
            continue;
        }
        for spec in &manifest.layer(&step.sig)?.params {
            expected.insert((si, spec.name.clone()), spec.shape.clone());
        }
    }

    let mut diags = Vec::new();
    let mut seen: BTreeSet<(usize, String)> = BTreeSet::new();
    for entry in doc.req("params")?.as_arr()? {
        let si = entry.req("step")?.as_usize()?;
        let name = entry.req("name")?.as_str()?.to_string();
        let shape = entry.req("shape")?.as_usize_vec()?;
        match expected.get(&(si, name.clone())) {
            None => diags.push(Diagnostic::error(
                codes::CKPT_UNKNOWN_PARAM, Some(si),
                format!("checkpoint records param {name:?} at step {si}, \
                         which network {} does not have", def.name))),
            Some(want) if *want != shape => diags.push(Diagnostic::error(
                codes::CKPT_SHAPE_MISMATCH, Some(si),
                format!("checkpoint param {name:?} at step {si} has shape \
                         {shape:?}, spec says {want:?}"))),
            Some(_) => {
                seen.insert((si, name));
            }
        }
    }

    for ((si, name), shape) in &expected {
        if !seen.contains(&(*si, name.clone())) {
            diags.push(Diagnostic::error(
                codes::CKPT_MISSING_PARAM, Some(*si),
                format!("checkpoint does not record param {name:?} \
                         {shape:?} at step {si}; loading it would \
                         silently keep the random init")));
        }
    }

    Ok(diags)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::has_errors;
    use crate::api::Engine;

    fn temp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir()
            .join(format!("flowcheck_{tag}_{}", std::process::id()))
    }

    #[test]
    fn clean_checkpoint_verifies_empty() {
        let dir = temp("clean");
        let engine = Engine::native().unwrap();
        let flow = engine.flow("realnvp2d").unwrap();
        let params = flow.init_params(7).unwrap();
        params.save(&dir, "realnvp2d").unwrap();
        let diags = verify_checkpoint_index(engine.manifest(), &flow.def,
                                            &dir).unwrap();
        assert!(diags.is_empty(), "{diags:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncated_index_reports_every_missing_param() {
        let dir = temp("trunc");
        let engine = Engine::native().unwrap();
        let flow = engine.flow("realnvp2d").unwrap();
        let params = flow.init_params(7).unwrap();
        params.save(&dir, "realnvp2d").unwrap();
        // drop half the recorded params — ParamStore::load would accept
        // this silently, the static check must not
        let text = std::fs::read_to_string(dir.join("index.json")).unwrap();
        let mut doc = Json::parse(&text).unwrap();
        let dropped;
        {
            let Json::Obj(m) = &mut doc else { panic!("index not an obj") };
            let Some(Json::Arr(entries)) = m.get_mut("params") else {
                panic!("no params array")
            };
            dropped = entries.len() - entries.len() / 2;
            entries.truncate(entries.len() / 2);
        }
        std::fs::write(dir.join("index.json"), doc.to_string()).unwrap();

        let diags = verify_checkpoint_index(engine.manifest(), &flow.def,
                                            &dir).unwrap();
        assert!(has_errors(&diags));
        let missing = diags.iter()
            .filter(|d| d.code == codes::CKPT_MISSING_PARAM)
            .count();
        assert_eq!(missing, dropped, "{diags:?}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
