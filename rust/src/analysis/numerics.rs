//! Static numeric-range lints: interval propagation of activation
//! scale bounds through the layer program, catching f32 range hazards
//! before any execution.
//!
//! Every scaling layer declares (or defaults) a static interval for its
//! multiplicative scale:
//!
//! * affine couplings (`glowcpl`, `densecpl`, `condcpl`, `hint`) bound
//!   their raw conditioner output by `cfg.raw_bound` (default 16) and
//!   push it through `cfg.scale_act` (default `"sigmoid2"`, i.e.
//!   `s = 2*sigmoid(r)`; `"exp"` means `s = exp(r)`);
//! * `actnorm` declares `cfg.scale_min` / `cfg.scale_max` (defaults
//!   `[0.5, 2]`, the data-dependent-init regime);
//! * `conv1x1` (orthogonal), `haar`, `permute`, `addcpl`, and `hyper`
//!   are volume-preserving: scale interval `[1, 1]`.
//!
//! Three diagnostic codes come out of the walk:
//!
//! * [`codes::EXP_OVERFLOW`] (error) — an `exp` scale activation whose
//!   raw bound exceeds `ln(f32::MAX)`, or a propagated amplitude bound
//!   that leaves double range entirely: the forward pass can overflow.
//! * [`codes::ACTNORM_DEGENERATE_SCALE`] (error) — a declared actnorm
//!   scale interval that is empty, non-positive, or below f32's
//!   smallest normal: the inverse divides by (effectively) zero.
//! * [`codes::LOGDET_UNDERFLOW`] (warning) — a scale lower bound that
//!   underflows f32's smallest normal, so `ln(s)` in the log-det sum
//!   can hit `-inf` while the forward values still look finite.
//!
//! The builtin catalog carries none of these cfg keys, so it lints
//! clean under the defaults — the pass only fires on definitions that
//! declare a hazardous regime (see `tests/analysis.rs`, which splices
//! cfg overrides to trip each code).

use super::{codes, Diagnostic};
use crate::runtime::{LayerMeta, Manifest, NetworkMeta};

/// `ln(f32::MAX)`: an `exp` scale with a raw bound past this overflows.
const LN_F32_MAX: f64 = 88.722_839;
/// f32's smallest positive normal; below this, `ln` and division are
/// effectively operating on zero.
const F32_MIN_NORMAL: f64 = 1.175_494_4e-38;
/// `ln(f64::MAX)`: past this, even the propagated double-precision
/// amplitude bound is infinite.
const LN_F64_MAX: f64 = 709.782_712;

fn cfg_f64(meta: &LayerMeta, key: &str) -> Option<f64> {
    meta.cfg.get(key).and_then(|v| v.as_f64().ok())
}

fn cfg_str(meta: &LayerMeta, key: &str) -> Option<String> {
    meta.cfg.get(key).and_then(|v| v.as_str().ok().map(str::to_string))
}

fn sigmoid(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

/// The static scale interval `[s_lo, s_hi]` one layer can apply, plus
/// any local diagnostics its declaration earns.
fn scale_interval(i: usize, meta: &LayerMeta,
                  diags: &mut Vec<Diagnostic>) -> (f64, f64) {
    match meta.kind.as_str() {
        "glowcpl" | "densecpl" | "condcpl" | "hint" => {
            let r = cfg_f64(meta, "raw_bound").unwrap_or(16.0);
            let act = cfg_str(meta, "scale_act")
                .unwrap_or_else(|| "sigmoid2".to_string());
            match act.as_str() {
                "exp" => {
                    if r > LN_F32_MAX {
                        diags.push(Diagnostic::error(
                            codes::EXP_OVERFLOW, Some(i),
                            format!("layer {}: exp scale with raw bound \
                                     {r} > ln(f32::MAX) ~ {LN_F32_MAX:.1} \
                                     can overflow the forward pass",
                                    meta.sig)));
                    }
                    ((-r).exp(), r.exp())
                }
                // sigmoid2 and anything unrecognized: bounded by (0, 2)
                _ => (2.0 * sigmoid(-r), 2.0 * sigmoid(r)),
            }
        }
        "actnorm" => {
            let lo = cfg_f64(meta, "scale_min").unwrap_or(0.5);
            let hi = cfg_f64(meta, "scale_max").unwrap_or(2.0);
            if lo <= 0.0 || lo < F32_MIN_NORMAL || lo > hi {
                diags.push(Diagnostic::error(
                    codes::ACTNORM_DEGENERATE_SCALE, Some(i),
                    format!("layer {}: declared scale interval \
                             [{lo:e}, {hi:e}] is degenerate — the \
                             inverse divides by a scale at or below \
                             f32's smallest normal", meta.sig)));
                return (1.0, 1.0); // don't double-report downstream
            }
            (lo, hi)
        }
        // volume-preserving / orthogonal kinds
        _ => (1.0, 1.0),
    }
}

/// Walk one network's layer program propagating scale-amplitude bounds;
/// returns all numeric-range findings. Unknown sigs and split markers
/// are skipped — the shape verifier owns those.
pub fn check_network(manifest: &Manifest, net: &NetworkMeta)
                     -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    // cumulative log of the worst-case amplitude gain so far
    let mut log_amp = 0.0f64;
    let mut amp_reported = false;
    for (i, sig) in net.layers.iter().enumerate() {
        let Ok(meta) = manifest.layer(sig) else { continue };
        let (s_lo, s_hi) = scale_interval(i, meta, &mut diags);
        if s_lo > 0.0 && s_lo < F32_MIN_NORMAL {
            diags.push(Diagnostic::warning(
                codes::LOGDET_UNDERFLOW, Some(i),
                format!("layer {}: scale lower bound {s_lo:e} underflows \
                         f32's smallest normal — ln(s) in the log-det \
                         sum can reach -inf", meta.sig)));
        }
        log_amp += s_hi.max(f64::MIN_POSITIVE).ln();
        if !amp_reported && log_amp > LN_F64_MAX {
            amp_reported = true;
            diags.push(Diagnostic::error(
                codes::EXP_OVERFLOW, Some(i),
                format!("propagated activation amplitude bound becomes \
                         non-finite at layer {} (cumulative log-gain \
                         {log_amp:.1} > ln(f64::MAX))", meta.sig)));
        }
    }
    diags
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::builtin_manifest;

    #[test]
    fn builtin_catalog_is_numerically_clean() {
        let m = builtin_manifest().unwrap();
        for net in m.networks.values() {
            let diags = check_network(&m, net);
            assert!(diags.is_empty(), "{}: {diags:?}", net.name);
        }
    }

    #[test]
    fn default_coupling_interval_is_strictly_inside_f32_range() {
        // sigmoid2 with the default raw bound: s in (4e-8, 2) — no
        // overflow, no underflow, logdet finite
        let lo = 2.0 * sigmoid(-16.0);
        assert!(lo > F32_MIN_NORMAL && lo < 1.0);
        assert!(2.0 * sigmoid(16.0) < 2.0 + 1e-9);
    }
}
