//! `flowcheck`: a static flow verifier and exact memory cost model.
//!
//! Everything in this module runs over a manifest / [`NetworkDef`] layer
//! program *without executing it*:
//!
//! * [`verify_network`] / [`verify_manifest`] — shape/width propagation
//!   through every layer kind, split/concat bookkeeping, multiscale
//!   squeeze factors, conditional-input widths, and an invertibility
//!   audit (each kind must declare a total inverse; the composed chain
//!   must be bijective on its stated dimensions). Every violation is a
//!   structured [`Diagnostic`] instead of a runtime panic.
//! * [`predict_peak`] / [`schedule_peaks`] — the static memory planner:
//!   the *exact* predicted ledger peak bytes per
//!   [`ActivationSchedule`](crate::coordinator::ActivationSchedule),
//!   pinned `predicted == measured` against the coordinator's ledger in
//!   tests and as equality-pin metrics in the memory perf suites.
//! * [`verify_checkpoint_index`] — checkpoint `index.json` contents
//!   validated against the spec statically, before any weight loads.
//! * [`train_cost`] / [`inference_cost`] / [`schedule_costs`] — the
//!   static compute cost model: exact (canonically defined) arithmetic
//!   op and bytes-moved counts per schedule, replaying the executor's
//!   recompute order the same way the memory planner replays its
//!   allocs; pinned against the independent Python mirror
//!   `python/tests/test_cost_model.py`.
//! * [`choose_schedule`] — automatic schedule selection (`--mode auto`):
//!   the cheapest-compute schedule whose predicted peak fits a byte
//!   budget, decided entirely statically.
//! * [`numerics::check_network`] — interval propagation of activation
//!   scale bounds, catching f32 overflow/underflow hazards
//!   (`exp-overflow`, `actnorm-degenerate-scale`, `logdet-underflow`)
//!   as part of the [`verify_network`] diagnostic stream.
//!
//! Gated everywhere a network enters the system: `Engine::build`, the
//! serve [`Registry`](crate::serve::Registry), and the `invertnet lint`
//! CLI verb.
//!
//! [`NetworkDef`]: crate::flow::NetworkDef

use std::fmt;

mod checkpoint;
mod cost;
pub mod numerics;
mod planner;
mod schedule;
mod verify;

pub use checkpoint::verify_checkpoint_index;
pub use cost::{inference_cost, layer_entry_costs, sample_cost,
               schedule_costs, train_cost, Cost, LayerCost};
pub use planner::{predict_peak, schedule_peaks};
pub use schedule::{candidate_schedules, choose_schedule, ScheduleChoice};
pub use verify::{verify_checkpoint_k, verify_manifest, verify_network,
                 INVERTIBLE_KINDS};

/// How bad a [`Diagnostic`] is. `Error` means the network must be
/// rejected; `Warning` flags suspicious-but-executable definitions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

/// The stable machine-readable diagnostic codes, one per distinct
/// violation class. Tests and CI smoke checks match on these strings, so
/// they are part of the `invertnet-lint/v1` contract.
pub mod codes {
    /// A network references a layer sig the manifest doesn't define.
    pub const UNKNOWN_LAYER: &str = "unknown-layer";
    /// A layer's declared input shape disagrees with the propagated flow
    /// shape at its position in the chain.
    pub const SHAPE_MISMATCH: &str = "shape-mismatch";
    /// A split marker with a bad channel count (`zc == 0` or `zc >= c`)
    /// or an input shape that disagrees with the propagated flow shape.
    pub const BAD_SPLIT: &str = "bad-split";
    /// A squeeze (haar) layer with a non-4D input, odd spatial dims, or
    /// an output other than `[n, h/2, w/2, 4c]`.
    pub const BAD_SQUEEZE: &str = "bad-squeeze";
    /// A non-squeeze layer that changes its shape — width changes are
    /// only sanctioned at squeeze points, anywhere else the chain can't
    /// be bijective.
    pub const WIDTH_CHANGE: &str = "width-change";
    /// A layer consumes a conditioning input the network doesn't declare,
    /// or declares a different conditioning width than the network.
    pub const COND_MISMATCH: &str = "cond-mismatch";
    /// The network declares a conditioning input no layer consumes.
    pub const DANGLING_COND: &str = "dangling-cond";
    /// The declared latent shapes disagree with the ones derived from
    /// the split markers and the final flow shape (dangling split half).
    pub const LATENT_MISMATCH: &str = "latent-mismatch";
    /// Total latent elements differ from input elements: the composed
    /// chain is not a bijection on its stated dimensions.
    pub const NOT_BIJECTIVE: &str = "not-bijective";
    /// A layer kind that does not declare a total inverse.
    pub const NO_INVERSE: &str = "no-inverse";
    /// A checkpoint-every-K schedule with `K == 0` (error) or `K` larger
    /// than the network depth (warning: degenerates to invertible + one
    /// tape entry).
    pub const BAD_CHECKPOINT_K: &str = "bad-checkpoint-k";
    /// A checkpoint index records a param the spec doesn't have.
    pub const CKPT_UNKNOWN_PARAM: &str = "ckpt-unknown-param";
    /// A checkpoint param's recorded shape disagrees with the spec.
    pub const CKPT_SHAPE_MISMATCH: &str = "ckpt-shape-mismatch";
    /// A spec param the checkpoint index doesn't record — loading would
    /// silently keep the random init for it.
    pub const CKPT_MISSING_PARAM: &str = "ckpt-missing-param";
    /// An `exp` coupling-scale activation whose declared raw bound (or
    /// the propagated amplitude bound) exceeds f32 range: the forward
    /// pass can overflow to `inf`.
    pub const EXP_OVERFLOW: &str = "exp-overflow";
    /// An actnorm scale interval that is empty, non-positive, or below
    /// f32's smallest normal: the inverse divides by ~zero.
    pub const ACTNORM_DEGENERATE_SCALE: &str = "actnorm-degenerate-scale";
    /// A scale lower bound that underflows f32, so `ln(s)` in the
    /// log-det sum can reach `-inf` while forward values stay finite.
    pub const LOGDET_UNDERFLOW: &str = "logdet-underflow";
}

/// One structured verifier finding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    pub severity: Severity,
    /// Step index in the network's layer program, when the finding is
    /// attributable to one step; `None` for whole-network findings.
    pub layer_idx: Option<usize>,
    /// A stable code from [`codes`].
    pub code: &'static str,
    pub message: String,
}

impl Diagnostic {
    pub fn error(code: &'static str, layer_idx: Option<usize>,
                 message: String) -> Diagnostic {
        Diagnostic { severity: Severity::Error, layer_idx, code, message }
    }

    pub fn warning(code: &'static str, layer_idx: Option<usize>,
                   message: String) -> Diagnostic {
        Diagnostic { severity: Severity::Warning, layer_idx, code, message }
    }

    pub fn is_error(&self) -> bool {
        self.severity == Severity::Error
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let sev = match self.severity {
            Severity::Warning => "warning",
            Severity::Error => "error",
        };
        match self.layer_idx {
            Some(i) => write!(f, "{sev}[{}] step {i}: {}", self.code,
                              self.message),
            None => write!(f, "{sev}[{}]: {}", self.code, self.message),
        }
    }
}

/// True if any diagnostic in the slice is error-severity.
pub fn has_errors(diags: &[Diagnostic]) -> bool {
    diags.iter().any(Diagnostic::is_error)
}
