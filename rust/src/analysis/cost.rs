//! The static compute cost model: exact per-layer arithmetic-op and
//! bytes-moved counts for `forward`, `inverse`, and both VJP entries of
//! every layer kind, composed into per-schedule training-step and
//! inference totals by replaying the executor's recompute order — the
//! same walk [`predict_peak`](super::predict_peak) does for allocs.
//!
//! "Exact" means *exactly defined*: the op counts below are a canonical
//! arithmetic model (1 MAC = 2 flops, elementwise ops = 1 flop/element,
//! SAME-padded 3x3 convs counted with clipped border taps), implemented
//! once here and once, independently, in the Python mirror
//! `python/tests/test_cost_model.py`. Both implementations are pinned
//! against the committed fixture `python/tests/data/cost_model_pins.json`
//! for every builtin example net x three canonical schedules, so the two
//! cost models can never drift apart silently.
//!
//! ## The canonical op-count table
//!
//! Helpers (`E` = input elements, `n` = batch, `c` = channels,
//! `R = E/c` rows, 4D spatial `P = n*h*w`):
//!
//! * `taps(x, 1) = x`; `taps(x, 3) = max(3x - 2, 1)` — clipped-border
//!   tap count of a SAME conv along one length-`x` axis.
//! * `conv_macs(n,h,w,ci,co,k) = n * taps(h,k) * taps(w,k) * ci * co`
//! * conv flops (with bias) `= 2*conv_macs + n*h*w*co`
//! * `cnn(ci,hid,co)` = conv3(ci,hid) + relu + conv1(hid,hid) + relu +
//!   conv3(hid,co); `mlp(din,hid,dout)` analogous with dense layers.
//! * a conditioner's VJP costs `3x` its apply (forward recompute + the
//!   dx pass + the dW pass).
//!
//! Per kind (fwd / inv / vjp_stored; the untaped `backward` entry is
//! `inv + vjp_stored` because it inverse-recomputes first):
//!
//! | kind     | fwd                  | inv                  | vjp_stored             |
//! |----------|----------------------|----------------------|------------------------|
//! | actnorm  | `2E + 2c + n`        | `2E + c`             | `3E + 2c`              |
//! | conv1x1  | `B + 2Rc^2 + n`      | `B + 2Rc^2`          | `12c^3 + 4Rc^2`        |
//! | glowcpl  | `g + 8Pc2 + n`       | `g + 6Pc2 + n`       | `3g + 10Pc2 + n`       |
//! | addcpl   | `g + Pc2 + n`        | `g + Pc2 + n`        | `3g + Pc2`             |
//! | densecpl | `g + 8nd2 + n`       | `g + 6nd2 + n`       | `3g + 10nd2 + n`       |
//! | condcpl  | like densecpl with `g = mlp(d1 + dcond, hid, 2*d2)`    |
//! | haar     | `4E`                 | `4E`                 | `4E`                   |
//! | permute  | `0`                  | `0`                  | `0`                    |
//! | hyper    | `2g + Pc + n`        | `2g + Pc + n`        | `6g + 2Pc`             |
//! | hint     | sum over `hint_nodes(d, depth)` of the densecpl terms  |
//!
//! where `B = 6c^2 + 6c` (the householder W build), `g` is the layer's
//! conditioner apply cost, `c2`/`d2` the transformed half, and sigmoid2
//! scale activations count 4 flops/element (8/6/10 = act + affine +
//! logdet terms per entry).
//!
//! Bytes moved use one kind-agnostic protocol model (4 bytes/element):
//! fwd reads x/params/cond and writes y + logdet; inv drops the logdet;
//! vjp_stored reads x/dy/params/cond and writes dx + dtheta.
//!
//! On top of the protocol bytes, the model prices the **packed-GEMM
//! traffic** of the vectorized kernels (`backend::math`): every GEMM
//! operand `W (k x m)` is repacked once per entry call into 8-wide
//! column panels, a write of `k * ceil8(m)` elements (tail columns are
//! zero-padded up to the panel width). Per kind the packed matrices are
//! the conditioner weight matrices (conv weights as their `9*ci x co`
//! im2col form), plus the built `c x c` householder matrix for conv1x1.
//! fwd and inv pack once; vjp_stored packs twice (the forward recompute
//! and the dx backprop — the dW pass is the deliberately scalar,
//! order-pinned kernel and never packs):
//!
//! | kind     | packed elements per call                                  |
//! |----------|-----------------------------------------------------------|
//! | cnn g    | `9*ci*ceil8(hid) + hid*ceil8(hid) + 9*hid*ceil8(co)`      |
//! | mlp g    | `din*ceil8(hid) + hid*ceil8(hid) + hid*ceil8(dout)`       |
//! | conv1x1  | `c * ceil8(c)`                                            |
//! | hyper    | `9*(c/2)*ceil8(hid)`                                      |
//! | others   | conditioner table above; actnorm/haar/permute/split: `0`  |

use crate::coordinator::memory::BYTES_PER_ELEM;
use crate::coordinator::{ActivationSchedule, CheckpointEveryK, ExecMode};
use crate::flow::{NetworkDef, StepKind};
use crate::runtime::builtin::hint_nodes;
use crate::runtime::{LayerMeta, Manifest};
use anyhow::{bail, Result};

/// Arithmetic ops + bytes moved for one entry or one composed program.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Cost {
    pub flops: u64,
    pub bytes: u64,
}

impl Cost {
    fn add(self, other: Cost) -> Cost {
        Cost { flops: self.flops + other.flops,
               bytes: self.bytes + other.bytes }
    }
}

/// The four per-layer entry costs the executor can dispatch.
#[derive(Debug, Clone, Copy)]
pub struct LayerCost {
    pub fwd: Cost,
    pub inv: Cost,
    /// `backward_stored`: VJP from a taped input.
    pub vjp_stored: Cost,
    /// `backward`: inverse-recompute + VJP (`inv + vjp_stored`).
    pub vjp: Cost,
}

fn numel(shape: &[usize]) -> u64 {
    shape.iter().map(|&d| d as u64).product()
}

/// Clipped-border tap count of a SAME conv along one axis.
fn taps(x: u64, k: usize) -> u64 {
    match k {
        1 => x,
        3 => (3 * x).saturating_sub(2).max(1),
        _ => unreachable!("only 1x1 and 3x3 convs exist in the catalog"),
    }
}

fn conv_macs(n: u64, h: u64, w: u64, ci: u64, co: u64, k: usize) -> u64 {
    n * taps(h, k) * taps(w, k) * ci * co
}

/// One SAME conv with bias: 2 flops/MAC + the bias add.
fn conv_flops(n: u64, h: u64, w: u64, ci: u64, co: u64, k: usize) -> u64 {
    2 * conv_macs(n, h, w, ci, co, k) + n * h * w * co
}

/// The 3-conv conditioner CNN: conv3 -> relu -> conv1 -> relu -> conv3.
fn cnn_flops(n: u64, h: u64, w: u64, ci: u64, hid: u64, co: u64) -> u64 {
    conv_flops(n, h, w, ci, hid, 3) + n * h * w * hid
        + conv_flops(n, h, w, hid, hid, 1) + n * h * w * hid
        + conv_flops(n, h, w, hid, co, 3)
}

/// One dense layer with bias.
fn lin_flops(n: u64, a: u64, b: u64) -> u64 {
    2 * n * a * b + n * b
}

/// The 3-layer conditioner MLP: lin -> relu -> lin -> relu -> lin.
fn mlp_flops(n: u64, din: u64, hid: u64, dout: u64) -> u64 {
    lin_flops(n, din, hid) + n * hid + lin_flops(n, hid, hid) + n * hid
        + lin_flops(n, hid, dout)
}

/// Kind-agnostic bytes-moved model for the four entries (see module doc).
fn entry_bytes(meta: &LayerMeta) -> (u64, u64, u64) {
    let e_in = numel(&meta.in_shape);
    let e_out = numel(&meta.out_shape);
    let n = meta.in_shape[0] as u64;
    let params = meta.param_count() as u64;
    let e_cond = meta.cond_shape.as_deref().map_or(0, numel);
    let b = BYTES_PER_ELEM as u64;
    let fwd = b * (e_in + e_out + n + params + e_cond);
    let inv = b * (e_in + e_out + params + e_cond);
    let vjps = b * (2 * e_in + e_out + 2 * params + e_cond);
    (fwd, inv, vjps)
}

/// Round a GEMM column count up to the vectorized kernels' 8-wide panel.
fn ceil8(m: u64) -> u64 {
    m.div_ceil(8) * 8
}

/// Packed-panel write of the CNN conditioner's three weight matrices
/// (convs in their im2col `taps x co` form).
fn cnn_pack(ci: u64, hid: u64, co: u64) -> u64 {
    9 * ci * ceil8(hid) + hid * ceil8(hid) + 9 * hid * ceil8(co)
}

/// Packed-panel write of the MLP conditioner's three weight matrices.
fn mlp_pack(din: u64, hid: u64, dout: u64) -> u64 {
    din * ceil8(hid) + hid * ceil8(hid) + hid * ceil8(dout)
}

/// Elements written into 8-wide GEMM panels per entry call (module doc).
fn pack_elems(meta: &LayerMeta) -> Result<u64> {
    let c = *meta.in_shape.last().unwrap_or(&1) as u64;
    Ok(match meta.kind.as_str() {
        "actnorm" | "haar" | "permute" => 0,
        "conv1x1" => c * ceil8(c),
        "glowcpl" => {
            let (c1, c2) = (c / 2, c - c / 2);
            cnn_pack(c1, hidden_of(meta)?, 2 * c2)
        }
        "addcpl" => {
            let (c1, c2) = (c / 2, c - c / 2);
            cnn_pack(c1, hidden_of(meta)?, c2)
        }
        "densecpl" | "condcpl" => {
            let d = meta.in_shape[1] as u64;
            let (d1, d2) = (d / 2, d - d / 2);
            let dcond = meta.cond_shape.as_ref().map_or(0, |s| s[1] as u64);
            mlp_pack(d1 + dcond, hidden_of(meta)?, 2 * d2)
        }
        "hyper" => 9 * (c / 2) * ceil8(hidden_of(meta)?),
        "hint" => {
            let d = meta.in_shape[1] as usize;
            let hid = hidden_of(meta)?;
            let depth = meta.cfg_usize("depth").unwrap_or(1);
            hint_nodes(d, depth).iter()
                .map(|(_, d1, d2)| mlp_pack(*d1 as u64, hid, 2 * *d2 as u64))
                .sum()
        }
        other => bail!("no pack model for layer kind {other:?}"),
    })
}

fn hidden_of(meta: &LayerMeta) -> Result<u64> {
    match meta.cfg_usize("hidden") {
        Some(h) => Ok(h as u64),
        None => bail!("layer {} ({}) has no `hidden` in cfg — the cost \
                       model needs the conditioner width", meta.sig,
                      meta.kind),
    }
}

/// The canonical per-entry cost of one layer (see the module-level table).
pub fn layer_entry_costs(meta: &LayerMeta) -> Result<LayerCost> {
    let e = numel(&meta.in_shape);
    let n = meta.in_shape[0] as u64;
    let c = *meta.in_shape.last().unwrap_or(&1) as u64;
    let r = e / c.max(1);
    let (fwd, inv, vjps) = match meta.kind.as_str() {
        "actnorm" => (2 * e + 2 * c + n, 2 * e + c, 3 * e + 2 * c),
        "conv1x1" => {
            let build = 6 * c * c + 6 * c;
            (build + 2 * r * c * c + n,
             build + 2 * r * c * c,
             12 * c * c * c + 4 * r * c * c)
        }
        "glowcpl" | "addcpl" => {
            let (h, w) = (meta.in_shape[1] as u64, meta.in_shape[2] as u64);
            let (c1, c2) = (c / 2, c - c / 2);
            let hid = hidden_of(meta)?;
            let p2 = n * h * w * c2;
            if meta.kind == "glowcpl" {
                let g = cnn_flops(n, h, w, c1, hid, 2 * c2);
                (g + 8 * p2 + n, g + 6 * p2 + n, 3 * g + 10 * p2 + n)
            } else {
                let g = cnn_flops(n, h, w, c1, hid, c2);
                (g + p2 + n, g + p2 + n, 3 * g + p2)
            }
        }
        "densecpl" | "condcpl" => {
            let d = meta.in_shape[1] as u64;
            let (d1, d2) = (d / 2, d - d / 2);
            let hid = hidden_of(meta)?;
            let dcond = meta.cond_shape.as_ref()
                .map_or(0, |s| s[1] as u64);
            let g = mlp_flops(n, d1 + dcond, hid, 2 * d2);
            (g + 8 * n * d2 + n, g + 6 * n * d2 + n,
             3 * g + 10 * n * d2 + n)
        }
        "haar" => (4 * e, 4 * e, 4 * e),
        "permute" => (0, 0, 0),
        "hyper" => {
            let (h, w) = (meta.in_shape[1] as u64, meta.in_shape[2] as u64);
            let hid = hidden_of(meta)?;
            let g = 2 * conv_macs(n, h, w, c / 2, hid, 3) + n * h * w * hid;
            let pc = n * h * w * c;
            (2 * g + pc + n, 2 * g + pc + n, 6 * g + 2 * pc)
        }
        "hint" => {
            let d = meta.in_shape[1] as u64;
            let hid = hidden_of(meta)?;
            let depth = meta.cfg_usize("depth").unwrap_or(1);
            let (mut f, mut i, mut v) = (n, n, n);
            for (_, d1, d2) in hint_nodes(d as usize, depth) {
                let (d1, d2) = (d1 as u64, d2 as u64);
                let g = mlp_flops(n, d1, hid, 2 * d2);
                f += g + 8 * n * d2;
                i += g + 6 * n * d2;
                v += 3 * g + 10 * n * d2;
            }
            (f, i, v)
        }
        other => bail!("no cost model for layer kind {other:?}"),
    };
    let (bf, bi, bv) = entry_bytes(meta);
    // packed-GEMM panel traffic on top of the protocol bytes: fwd/inv
    // pack once, vjp_stored twice (recompute + dx; the dW kernel is
    // scalar and order-pinned, it never packs)
    let pack = BYTES_PER_ELEM as u64 * pack_elems(meta)?;
    let fwd = Cost { flops: fwd, bytes: bf + pack };
    let inv = Cost { flops: inv, bytes: bi + pack };
    let vjp_stored = Cost { flops: vjps, bytes: bv + 2 * pack };
    Ok(LayerCost { fwd, inv, vjp_stored, vjp: inv.add(vjp_stored) })
}

/// A coordinator-native split/join: pure data movement, no arithmetic.
fn split_cost(in_shape: &[usize]) -> Cost {
    Cost { flops: 0, bytes: 2 * BYTES_PER_ELEM as u64 * numel(in_shape) }
}

/// The gaussian log-density head over one latent shape.
fn logp_cost(shape: &[usize]) -> Cost {
    let n = shape[0] as u64;
    let k = numel(shape) / n.max(1);
    Cost { flops: 2 * n * k + 2 * n,
           bytes: BYTES_PER_ELEM as u64 * (n * k + n) }
}

/// The NLL gradient seed (`dz = z / n`) over one latent shape.
fn nll_seed_cost(shape: &[usize]) -> Cost {
    let n = shape[0] as u64;
    let k = numel(shape) / n.max(1);
    Cost { flops: n * k + n,
           bytes: BYTES_PER_ELEM as u64 * (2 * n * k + n) }
}

/// Mirror of the planner's taped-layer computation: which steps the
/// schedule stores.
fn taped_steps(def: &NetworkDef, schedule: &dyn ActivationSchedule)
               -> Vec<bool> {
    let n_layers = def.depth();
    let mut taped = vec![false; def.steps.len()];
    let mut layer_ord = 0usize;
    for (i, step) in def.steps.iter().enumerate() {
        if step.kind == StepKind::Layer {
            taped[i] = schedule.tape(layer_ord, n_layers);
            layer_ord += 1;
        }
    }
    taped
}

/// Predicted cost of one full training step (forward + loss heads +
/// backward) of `def` under `schedule`, replaying the executor's
/// entry-dispatch order: forward per step, `gaussian_logp` + the NLL
/// seed per latent, then the reversed walk dispatching `backward_stored`
/// for taped layers and `backward` (inverse-recompute) for untaped ones.
pub fn train_cost(def: &NetworkDef, manifest: &Manifest,
                  schedule: &dyn ActivationSchedule) -> Result<Cost> {
    let taped = taped_steps(def, schedule);
    let mut total = Cost::default();
    for step in &def.steps {
        total = total.add(match step.kind {
            StepKind::Split { .. } => split_cost(&step.in_shape),
            StepKind::Layer => {
                layer_entry_costs(manifest.layer(&step.sig)?)?.fwd
            }
        });
    }
    for latent in &def.latent_shapes {
        total = total.add(logp_cost(latent));
        total = total.add(nll_seed_cost(latent));
    }
    for (i, step) in def.steps.iter().enumerate().rev() {
        total = total.add(match step.kind {
            StepKind::Split { .. } => split_cost(&step.in_shape),
            StepKind::Layer => {
                let lc = layer_entry_costs(manifest.layer(&step.sig)?)?;
                if taped[i] { lc.vjp_stored } else { lc.vjp }
            }
        });
    }
    Ok(total)
}

/// Predicted cost of one log-density evaluation (forward + heads) —
/// schedule-independent: inference never tapes.
pub fn inference_cost(def: &NetworkDef, manifest: &Manifest)
                      -> Result<Cost> {
    let mut total = Cost::default();
    for step in &def.steps {
        total = total.add(match step.kind {
            StepKind::Split { .. } => split_cost(&step.in_shape),
            StepKind::Layer => {
                layer_entry_costs(manifest.layer(&step.sig)?)?.fwd
            }
        });
    }
    for latent in &def.latent_shapes {
        total = total.add(logp_cost(latent));
    }
    Ok(total)
}

/// Predicted cost of drawing one batch of samples (the reversed inverse
/// walk).
pub fn sample_cost(def: &NetworkDef, manifest: &Manifest) -> Result<Cost> {
    let mut total = Cost::default();
    for step in def.steps.iter().rev() {
        total = total.add(match step.kind {
            StepKind::Split { .. } => split_cost(&step.in_shape),
            StepKind::Layer => {
                layer_entry_costs(manifest.layer(&step.sig)?)?.inv
            }
        });
    }
    Ok(total)
}

/// Training-step costs under the three canonical schedules, labeled like
/// [`schedule_peaks`](super::schedule_peaks) — what `inspect` and the
/// lint `cost` block print per network.
pub fn schedule_costs(def: &NetworkDef, manifest: &Manifest)
                      -> Result<Vec<(String, Cost)>> {
    let schedules: [&dyn ActivationSchedule; 3] = [
        &ExecMode::Invertible,
        &ExecMode::Stored,
        &CheckpointEveryK(4),
    ];
    schedules.iter()
        .map(|s| Ok((s.label(), train_cost(def, manifest, *s)?)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::builtin_manifest;

    fn def_of(name: &str) -> (Manifest, NetworkDef) {
        let m = builtin_manifest().unwrap();
        let d = NetworkDef::resolve(&m, name).unwrap();
        (m, d)
    }

    #[test]
    fn stored_training_is_cheaper_than_invertible() {
        // recompute trades flops for memory: invertible must cost more
        for name in ["realnvp2d", "glow16", "nice16"] {
            let (m, d) = def_of(name);
            let inv = train_cost(&d, &m, &ExecMode::Invertible).unwrap();
            let sto = train_cost(&d, &m, &ExecMode::Stored).unwrap();
            assert!(inv.flops > sto.flops, "{name}: {inv:?} vs {sto:?}");
        }
    }

    #[test]
    fn checkpoint_cost_interpolates_between_the_pure_schedules() {
        let (m, d) = def_of("glow16");
        let inv = train_cost(&d, &m, &ExecMode::Invertible).unwrap().flops;
        let sto = train_cost(&d, &m, &ExecMode::Stored).unwrap().flops;
        let mid = train_cost(&d, &m, &CheckpointEveryK(4)).unwrap().flops;
        assert!(sto < mid && mid < inv, "{sto} {mid} {inv}");
    }

    #[test]
    fn inference_is_cheaper_than_any_training_schedule() {
        let (m, d) = def_of("hint8d");
        let infer = inference_cost(&d, &m).unwrap().flops;
        let sto = train_cost(&d, &m, &ExecMode::Stored).unwrap().flops;
        assert!(infer < sto, "{infer} {sto}");
        assert!(sample_cost(&d, &m).unwrap().flops > 0);
    }

    #[test]
    fn schedule_costs_reports_all_three_labels() {
        let (m, d) = def_of("hyper16");
        let rows = schedule_costs(&d, &m).unwrap();
        let labels: Vec<&str> =
            rows.iter().map(|(l, _)| l.as_str()).collect();
        assert_eq!(labels, ["invertible", "stored", "checkpoint_every_4"]);
        assert!(rows.iter().all(|&(_, c)| c.flops > 0 && c.bytes > 0));
    }

    #[test]
    fn every_builtin_layer_kind_has_a_cost() {
        let m = builtin_manifest().unwrap();
        for meta in m.layers.values() {
            let lc = layer_entry_costs(meta).unwrap();
            assert_eq!(lc.vjp.flops,
                       lc.inv.flops + lc.vjp_stored.flops, "{}", meta.sig);
            assert!(lc.fwd.bytes > 0, "{}", meta.sig);
        }
    }
}
