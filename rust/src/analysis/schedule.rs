//! Automatic schedule selection: search the schedule family with the
//! static memory planner + the static cost model and return the cheapest
//! schedule whose predicted peak fits a byte budget.
//!
//! This is the `--mode auto[:BUDGET]` backend: both predicates are fully
//! static ([`predict_peak`](super::predict_peak) is pinned
//! predicted == measured against the executor's ledger, and
//! [`train_cost`](super::train_cost) is pinned against the Python cost
//! mirror), so the choice is made — and infeasible budgets are rejected —
//! before a single tensor is allocated.

use std::sync::Arc;

use anyhow::{bail, Result};

use super::cost::train_cost;
use super::planner::predict_peak;
use crate::coordinator::{ActivationSchedule, CheckpointEveryK, ExecMode};
use crate::flow::NetworkDef;
use crate::runtime::Manifest;

/// One evaluated candidate: the schedule plus both static predictions.
pub struct ScheduleChoice {
    pub schedule: Arc<dyn ActivationSchedule>,
    pub label: String,
    /// Predicted training-step peak scheduling bytes (`predict_peak`).
    pub peak_bytes: i64,
    /// Predicted training-step arithmetic ops (`train_cost`).
    pub train_flops: u64,
}

/// The canonical search family: `stored`, `checkpoint:K` at power-of-two
/// intervals below the depth, and `invertible` — ordered cheapest-compute
/// first.
pub fn candidate_schedules(depth: usize)
                           -> Vec<Arc<dyn ActivationSchedule>> {
    let mut out: Vec<Arc<dyn ActivationSchedule>> =
        vec![Arc::new(ExecMode::Stored)];
    let mut k = 2usize;
    while k < depth {
        out.push(Arc::new(CheckpointEveryK(k)));
        k *= 2;
    }
    out.push(Arc::new(ExecMode::Invertible));
    out
}

/// Pick the cheapest-compute schedule whose predicted peak fits
/// `budget` bytes (`None` = unconstrained, which always selects pure
/// `stored`). Ties on flops break toward the lower peak. Errors when no
/// candidate fits — the caller learns the minimum feasible budget
/// without allocating anything.
pub fn choose_schedule(def: &NetworkDef, manifest: &Manifest,
                       budget: Option<i64>) -> Result<ScheduleChoice> {
    let mut best: Option<ScheduleChoice> = None;
    let mut min_peak = i64::MAX;
    for schedule in candidate_schedules(def.depth()) {
        let peak = predict_peak(def, schedule.as_ref());
        min_peak = min_peak.min(peak);
        if budget.is_some_and(|b| peak > b) {
            continue;
        }
        let flops = train_cost(def, manifest, schedule.as_ref())?.flops;
        let better = match &best {
            None => true,
            Some(b) => flops < b.train_flops
                || (flops == b.train_flops && peak < b.peak_bytes),
        };
        if better {
            let label = schedule.label();
            best = Some(ScheduleChoice {
                schedule, label, peak_bytes: peak, train_flops: flops,
            });
        }
    }
    match best {
        Some(c) => Ok(c),
        None => bail!(
            "no schedule fits the {} budget for {}: the minimum \
             predicted peak (invertible) is {} bytes",
            budget.map_or("unconstrained".to_string(), |b| b.to_string()),
            def.name, min_peak),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::builtin_manifest;

    fn def_of(name: &str) -> (Manifest, NetworkDef) {
        let m = builtin_manifest().unwrap();
        let d = NetworkDef::resolve(&m, name).unwrap();
        (m, d)
    }

    #[test]
    fn unconstrained_budget_selects_stored() {
        let (m, d) = def_of("glow16");
        let c = choose_schedule(&d, &m, None).unwrap();
        assert_eq!(c.label, "stored");
    }

    #[test]
    fn tight_budget_selects_invertible() {
        let (m, d) = def_of("glow16");
        let inv = predict_peak(&d, &ExecMode::Invertible);
        let c = choose_schedule(&d, &m, Some(inv)).unwrap();
        assert_eq!(c.label, "invertible");
        assert_eq!(c.peak_bytes, inv);
    }

    #[test]
    fn impossible_budget_is_rejected_with_the_floor() {
        let (m, d) = def_of("glow16");
        let inv = predict_peak(&d, &ExecMode::Invertible);
        let err = choose_schedule(&d, &m, Some(inv - 1)).unwrap_err();
        assert!(err.to_string().contains("minimum predicted peak"),
                "{err:#}");
    }

    #[test]
    fn intermediate_budget_selects_a_checkpoint_schedule() {
        let (m, d) = def_of("glow16");
        let inv = predict_peak(&d, &ExecMode::Invertible);
        let sto = predict_peak(&d, &ExecMode::Stored);
        assert!(inv < sto);
        // any checkpoint peak sits strictly between; budget just below
        // stored must pick a cheaper-than-invertible hybrid
        let c = choose_schedule(&d, &m, Some(sto - 1)).unwrap();
        assert!(c.label.starts_with("checkpoint_every_"), "{}", c.label);
        assert!(c.peak_bytes <= sto - 1);
    }
}
