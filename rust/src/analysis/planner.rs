//! The static memory planner: the *exact* predicted ledger peak per
//! activation schedule, computed by replaying the coordinator executor's
//! alloc/free order over shapes alone — no tensors, no backend.
//!
//! The simulation mirrors `Flow::train_step` statement for statement
//! (forward tracking, the dy seed, and the per-step backward churn,
//! including the `y: Option<Tracked>` recompute-activation handoff), so
//! `predict_peak(def, s) == StepResult::peak_sched_bytes` bit-for-bit
//! for every schedule. That equality is pinned in `tests/analysis.rs`
//! and emitted as `*_predicted_over_measured` pin metrics by the memory
//! perf suites.

use crate::coordinator::memory::bytes_of_shape;
use crate::coordinator::{ActivationSchedule, CheckpointEveryK, ExecMode};
use crate::flow::{NetworkDef, Step, StepKind};

/// A shape-only replay of [`MemoryLedger`](crate::MemoryLedger)'s
/// scheduling-class accounting. Params are never tracked by the
/// executor, so the simulated ledger starts (and the peak competes)
/// from zero live bytes — exactly what `reset_peaks()` leaves behind.
struct Sim {
    live: i64,
    peak: i64,
}

impl Sim {
    fn new() -> Sim {
        Sim { live: 0, peak: 0 }
    }

    fn alloc(&mut self, shape: &[usize]) {
        self.live += bytes_of_shape(shape);
        self.peak = self.peak.max(self.live);
    }

    fn free(&mut self, shape: &[usize]) {
        self.live -= bytes_of_shape(shape);
    }
}

/// After the taped input at step `i` is consumed, does an earlier step
/// still need a live activation? Mirrors the executor's
/// `y_needed_before`: true iff the nearest preceding *layer* step is
/// untaped (splits only reshape the activation on the way down).
fn y_needed_before(i: usize, taped: &[bool], steps: &[Step]) -> bool {
    for j in (0..i).rev() {
        match steps[j].kind {
            StepKind::Layer => return !taped[j],
            StepKind::Split { .. } => continue,
        }
    }
    false
}

/// Exact predicted `peak_sched_bytes` of one training step of `def`
/// under `schedule`.
pub fn predict_peak(def: &NetworkDef, schedule: &dyn ActivationSchedule)
                    -> i64 {
    let n_layers = def.depth();
    let mut taped = vec![false; def.steps.len()];
    let mut layer_ord = 0usize;
    for (i, step) in def.steps.iter().enumerate() {
        if step.kind == StepKind::Layer {
            taped[i] = schedule.tape(layer_ord, n_layers);
            layer_ord += 1;
        }
    }

    let mut sim = Sim::new();

    // ---- forward: the tracked input clone, then per-step tracking ----
    sim.alloc(&def.in_shape);
    for (i, step) in def.steps.iter().enumerate() {
        match step.kind {
            StepKind::Split { .. } => {
                let z = step.split_z_shape().expect("split step");
                sim.alloc(&z); // factored-out latent
                sim.alloc(&step.out_shape); // kept half
                sim.free(&step.in_shape); // consumed activation
            }
            StepKind::Layer => {
                sim.alloc(&step.out_shape);
                if !taped[i] {
                    sim.free(&step.in_shape); // recompute keeps nothing
                }
            }
        }
    }
    // the final activation is re-tracked as the last latent
    // (free-then-alloc of the same bytes: never a new peak)
    let final_shape: &[usize] = def.steps.last()
        .map(|s| s.out_shape.as_slice())
        .unwrap_or(&def.in_shape);

    // ---- backward: seed dy at the final latent, walk in reverse ------
    sim.alloc(final_shape);
    // `y` mirrors the executor's Option<Tracked> current activation
    let mut y: Option<&[usize]> = Some(final_shape);
    for (i, step) in def.steps.iter().enumerate().rev() {
        match step.kind {
            StepKind::Split { .. } => {
                let z = step.split_z_shape().expect("split step");
                sim.alloc(&step.in_shape); // joined dy
                sim.free(&step.out_shape); // old dy
                if y.is_some() {
                    sim.alloc(&step.in_shape); // re-joined activation
                    sim.free(&step.out_shape); // old kept-half activation
                    y = Some(&step.in_shape);
                }
                sim.free(&z); // the z latent is consumed here
            }
            StepKind::Layer if !taped[i] => {
                // inverse-recompute: dx replaces dy, x_rec replaces y
                sim.alloc(&step.in_shape);
                sim.free(&step.out_shape);
                sim.alloc(&step.in_shape);
                sim.free(&step.out_shape);
                y = Some(&step.in_shape);
            }
            StepKind::Layer => {
                // taped: the stored input supersedes the running y ...
                if y.take().is_some() {
                    sim.free(&step.out_shape);
                }
                // ... and is itself dropped unless an earlier untaped
                // layer still needs an activation to invert from
                let keep = y_needed_before(i, &taped, &def.steps);
                if !keep {
                    sim.free(&step.in_shape);
                }
                sim.alloc(&step.in_shape); // dx
                sim.free(&step.out_shape); // old dy
                if keep {
                    y = Some(&step.in_shape);
                }
            }
        }
    }

    sim.peak
}

/// Predicted peaks under the three canonical schedules, labeled with
/// each schedule's own `label()` — what `invertnet inspect` and `lint`
/// print per network.
pub fn schedule_peaks(def: &NetworkDef) -> Vec<(String, i64)> {
    let schedules: [&dyn ActivationSchedule; 3] = [
        &ExecMode::Invertible,
        &ExecMode::Stored,
        &CheckpointEveryK(4),
    ];
    schedules.iter()
        .map(|s| (s.label(), predict_peak(def, *s)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::planner::glow_flat_shape_def;

    #[test]
    fn hybrid_peak_sits_between_the_pure_schedules() {
        let def = glow_flat_shape_def(8, 64, 64, 3, 16);
        let inv = predict_peak(&def, &ExecMode::Invertible);
        let sto = predict_peak(&def, &ExecMode::Stored);
        let mid = predict_peak(&def, &CheckpointEveryK(6));
        assert!(inv < mid && mid < sto, "{inv} {mid} {sto}");
    }

    #[test]
    fn checkpoint_interval_interpolates_monotonically() {
        // larger K -> fewer tape entries -> lower peak
        let def = glow_flat_shape_def(8, 64, 64, 3, 24);
        let peaks: Vec<i64> = [2usize, 4, 8, 16]
            .iter()
            .map(|&k| predict_peak(&def, &CheckpointEveryK(k)))
            .collect();
        assert!(peaks.windows(2).all(|w| w[1] < w[0]), "{peaks:?}");
    }

    #[test]
    fn schedule_peaks_reports_all_three_labels() {
        let def = glow_flat_shape_def(8, 32, 32, 3, 8);
        let peaks = schedule_peaks(&def);
        let labels: Vec<&str> =
            peaks.iter().map(|(l, _)| l.as_str()).collect();
        assert_eq!(labels,
                   ["invertible", "stored", "checkpoint_every_4"]);
        assert!(peaks.iter().all(|&(_, b)| b > 0));
    }
}
