//! The request core: [`Server::handle`] maps one [`Request`] to one
//! [`Response`], independent of transport. Two fronts wrap it:
//!
//! * [`Server::serve_stdio`] — a read-line/write-line loop over any
//!   `BufRead`/`Write` pair, which is how tests and the CI smoke drive a
//!   full serving session hermetically;
//! * [`Server::serve_tcp`] — a JSON-lines loopback TCP listener with one
//!   lightweight thread per connection.
//!
//! Both exit after a `shutdown` request (in-flight work drains first),
//! and both answer through [`Server::answer_line`], which wraps the core
//! with per-request tracing: every line gets a trace id (the client's
//! `"trace_id"` if supplied, else a server-assigned `srv-<seq>`), its
//! parse/validate/encode phases are timed into the
//! `invertnet_serve_phase_*_us` histograms (the batch side contributes
//! queue_wait/batch_assembly/execute), and a `"timing":true` request
//! gets the per-phase block echoed back. Tracing only *adds* response
//! keys — payload fields are byte-identical with it on or off, so the
//! bit-invisibility contract of micro-batching is untouched.
//!
//! The TCP front additionally answers plain `GET` lines with minimal
//! HTTP: `/metrics` (the same Prometheus text exposition as the JSON
//! `metrics` op, so a stock scraper needs no adapter), `/healthz`
//! (liveness: the process answers), and `/readyz` (readiness: registry
//! warm, queue under half capacity, worker pool alive, not shutting
//! down — 503 with a per-check JSON body otherwise).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::posterior::analysis;
use crate::telemetry;
use crate::telemetry::events::{self, Level};
use crate::util::json::Json;
use crate::util::rng::Pcg64;

use super::batcher::{phase, BatchConfig, BatchTimes, Batcher, ReplyPayload,
                     ServeStats, Work};
use super::protocol::{decorate, ReqMeta, Request, Response, Timing};
use super::registry::{Registry, ServedModel};

/// `answer_line` dumps the flight recorder when this many error
/// responses land within [`ERROR_BURST_WINDOW`] on one server.
const ERROR_BURST_LEN: usize = 8;
const ERROR_BURST_WINDOW: Duration = Duration::from_secs(5);

/// Per-request conditioning check, run before a job may enter the batch
/// queue: a request with a missing/extra/mis-shaped cond fails alone
/// instead of erroring the whole coalesced pass it would have joined.
fn check_cond_request(m: &ServedModel, rows: usize, cond: Option<&crate::Tensor>)
                      -> Result<()> {
    match (&m.flow.def.cond_shape, cond) {
        (None, None) => Ok(()),
        (None, Some(_)) => {
            anyhow::bail!("network {} takes no cond", m.name)
        }
        (Some(_), None) => {
            anyhow::bail!("network {} requires a cond tensor with {rows} \
                           row(s)", m.name)
        }
        (Some(shape), Some(c)) => {
            if c.shape.len() != shape.len()
                || c.shape[1..] != shape[1..]
                || c.batch() != rows
            {
                anyhow::bail!(
                    "cond shape {:?} does not match network {} cond \
                     per-sample shape {:?} with {rows} row(s)",
                    c.shape, m.name, &shape[1..]);
            }
            Ok(())
        }
    }
}

/// Phase timings gathered while one request is handled; the front
/// assembles them (plus its own parse/encode clocks) into the optional
/// [`Timing`] echo.
#[derive(Default)]
struct HandleTimes {
    /// Pre-queue request work: model resolution, shape/cond validation,
    /// and (for sample/posterior) the per-request latent draw.
    validate_us: u64,
    /// Batch-side timings from the reply (zero for ops that never queue).
    batch: BatchTimes,
}

/// A long-lived inference service over a model [`Registry`].
pub struct Server {
    registry: Arc<Registry>,
    batcher: Batcher,
    stats: Arc<ServeStats>,
    shutdown: AtomicBool,
    /// Allow serving models whose weights are a random init (off by
    /// default so a missing checkpoint cannot silently serve noise).
    allow_untrained: bool,
    /// Source of server-assigned trace ids (`srv-<seq>`).
    req_seq: AtomicU64,
    /// Requests slower than this emit a `slow_request` event
    /// (CLI: `--slow-ms`). `None` disables the check.
    slow_us: Option<u64>,
    /// Error-response timestamps inside the burst window; a full window
    /// triggers a flight-recorder dump.
    recent_errors: Mutex<std::collections::VecDeque<Instant>>,
}

impl Server {
    pub fn new(registry: Registry, cfg: BatchConfig) -> Server {
        let stats = Arc::new(ServeStats::default());
        Server {
            registry: Arc::new(registry),
            batcher: Batcher::new(cfg, stats.clone()),
            stats,
            shutdown: AtomicBool::new(false),
            allow_untrained: false,
            req_seq: AtomicU64::new(0),
            slow_us: None,
            recent_errors: Mutex::new(std::collections::VecDeque::new()),
        }
    }

    /// Opt in to serving untrained (randomly initialized) models.
    pub fn allow_untrained(mut self) -> Server {
        self.allow_untrained = true;
        self
    }

    /// Emit a `slow_request` event for any request that takes longer
    /// than `ms` milliseconds end to end (CLI: `--slow-ms`).
    pub fn slow_ms(mut self, ms: u64) -> Server {
        self.slow_us = Some(ms.saturating_mul(1000));
        self
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::Relaxed)
    }

    // ------------------------------------------------------------------
    // Transport-agnostic core
    // ------------------------------------------------------------------

    /// Answer one request. Never panics on bad input — protocol and
    /// execution errors come back as [`Response::Error`].
    pub fn handle(&self, req: Request) -> Response {
        self.handle_traced(req, "").0
    }

    /// [`handle`](Self::handle) with the request's trace id threaded to
    /// the batch queue, returning the phase timings alongside.
    fn handle_traced(&self, req: Request, trace_id: &str)
                     -> (Response, HandleTimes) {
        let mut times = HandleTimes::default();
        let resp = match self.try_handle(req, trace_id, &mut times) {
            Ok(resp) => resp,
            Err(e) => {
                self.note_error();
                Response::err(format!("{e:#}"))
            }
        };
        (resp, times)
    }

    fn try_handle(&self, req: Request, trace_id: &str, t: &mut HandleTimes)
                  -> Result<Response> {
        match req {
            Request::Sample { model, n, temperature, seed, cond } => {
                let t_val = Instant::now();
                let m = self.model(model.as_deref())?;
                // validate BEFORE queueing: a bad request must fail alone,
                // never poison the valid requests it would coalesce with
                check_cond_request(&m, n, cond.as_ref())?;
                // each request draws its own latents from its own seed, so
                // the reply is bit-identical to a direct
                // `sample(&params, SampleOpts::new(n, &mut Pcg64::new(seed))
                //           .temperature(T).cond_opt(cond))`
                // no matter what it batches with
                let latents = m.flow.sample_latents(
                    n, temperature, &mut Pcg64::new(seed))?;
                t.validate_us = t_val.elapsed().as_micros() as u64;
                self.stats.record_phase(phase::VALIDATE, t.validate_us);
                let rx = self.batcher.submit_traced(
                    m, Work::Sample { latents, cond }, trace_id.to_string())?;
                let reply = rx.recv().context("serve worker hung up")??;
                t.batch = reply.times;
                match reply.payload {
                    ReplyPayload::Samples(x) => Ok(Response::Sample { x }),
                    ReplyPayload::Scores(_) => {
                        unreachable!("sample got scores")
                    }
                }
            }
            Request::Score { model, x, cond } => {
                let t_val = Instant::now();
                let m = self.model(model.as_deref())?;
                let want = &m.flow.def.in_shape;
                if x.batch() == 0 {
                    anyhow::bail!("score x has no rows");
                }
                if x.shape.len() != want.len() || x.shape[1..] != want[1..] {
                    anyhow::bail!(
                        "score x shape {:?} does not match network {} \
                         per-sample shape {:?}",
                        x.shape, m.name, &want[1..]);
                }
                check_cond_request(&m, x.batch(), cond.as_ref())?;
                t.validate_us = t_val.elapsed().as_micros() as u64;
                self.stats.record_phase(phase::VALIDATE, t.validate_us);
                let rx = self.batcher.submit_traced(
                    m, Work::Score { x, cond }, trace_id.to_string())?;
                let reply = rx.recv().context("serve worker hung up")??;
                t.batch = reply.times;
                match reply.payload {
                    ReplyPayload::Scores(log_density) => {
                        Ok(Response::Score { log_density })
                    }
                    ReplyPayload::Samples(_) => {
                        unreachable!("score got samples")
                    }
                }
            }
            Request::Posterior { model, y, n, temperature, seed,
                                 return_samples } => {
                let t_val = Instant::now();
                let m = self.model(model.as_deref())?;
                // tile the observation across the conditioning batch and
                // validate it exactly like a sample request, BEFORE
                // queueing (a bad y must fail alone, not poison a batch)
                let cond = analysis::tile_observation(&y, n)?;
                check_cond_request(&m, n, Some(&cond))?;
                // same generator as analysis::posterior_samples, so the
                // reply is bit-identical to the in-process call no matter
                // what this job coalesces with
                let latents = m.flow.sample_latents(
                    n, temperature, &mut Pcg64::new(seed))?;
                t.validate_us = t_val.elapsed().as_micros() as u64;
                self.stats.record_phase(phase::VALIDATE, t.validate_us);
                let rx = self.batcher.submit_traced(
                    m, Work::Sample { latents, cond: Some(cond) },
                    trace_id.to_string())?;
                let reply = rx.recv().context("serve worker hung up")??;
                t.batch = reply.times;
                match reply.payload {
                    ReplyPayload::Samples(x) => {
                        let s = analysis::summarize(&x);
                        Ok(Response::Posterior {
                            n,
                            mean: s.mean,
                            std: s.std,
                            samples: return_samples.then_some(x),
                        })
                    }
                    ReplyPayload::Scores(_) => {
                        unreachable!("posterior got scores")
                    }
                }
            }
            Request::Stats => Ok(Response::Stats(self.stats.snapshot(
                self.batcher.queue_depth() as u64,
                self.registry.len() as u64,
            ))),
            Request::Metrics => Ok(Response::Metrics {
                text: self.metrics_text(),
            }),
            Request::DebugDump => {
                let snap = self.stats.snapshot(
                    self.batcher.queue_depth() as u64,
                    self.registry.len() as u64);
                Ok(Response::DebugDump {
                    report: events::dump_report("debug-dump op", vec![
                        ("requests_total", Json::Num(snap.requests as f64)),
                        ("errors_total", Json::Num(snap.errors as f64)),
                        ("queue_depth", Json::Num(snap.queue_depth as f64)),
                    ]),
                })
            }
            Request::Shutdown => {
                self.shutdown.store(true, Ordering::Relaxed);
                events::emit(Level::Info, "shutdown", vec![
                    ("queue_depth",
                     Json::Num(self.batcher.queue_depth() as f64)),
                ]);
                Ok(Response::Shutdown)
            }
        }
    }

    fn model(&self, name: Option<&str>)
             -> Result<Arc<ServedModel>> {
        let m = self.registry.get(name)?;
        if !m.trained && !self.allow_untrained {
            anyhow::bail!(
                "model {:?} has untrained (randomly initialized) weights; \
                 load a checkpoint or start the server with untrained \
                 models explicitly allowed", m.name);
        }
        Ok(m)
    }

    /// Count one error response toward the burst window; a full window
    /// dumps the flight recorder (then resets, so a sustained error
    /// storm produces periodic dumps instead of one per request).
    fn note_error(&self) {
        let now = Instant::now();
        let mut errs = self.recent_errors.lock().unwrap();
        errs.push_back(now);
        while errs.front()
            .is_some_and(|t| now.duration_since(*t) > ERROR_BURST_WINDOW)
        {
            errs.pop_front();
        }
        if errs.len() >= ERROR_BURST_LEN {
            errs.clear();
            drop(errs);
            events::emit_dump("error burst", vec![
                ("burst_len", Json::Num(ERROR_BURST_LEN as f64)),
                ("window_s",
                 Json::Num(ERROR_BURST_WINDOW.as_secs() as f64)),
            ]);
        }
    }

    /// Full telemetry scrape: the process-global registry (span
    /// histograms, train/scratch series if this process also trains)
    /// merged with the serve-local instruments embedded in `ServeStats`
    /// and the model registry, rendered as Prometheus text exposition.
    pub fn metrics_text(&self) -> String {
        // refresh the point-in-time gauges before sampling them
        let _ = self.stats.snapshot(self.batcher.queue_depth() as u64,
                                    self.registry.len() as u64);
        let mut all: std::collections::BTreeMap<String, telemetry::Sample> =
            telemetry::global().snapshot().into_iter().collect();
        for (name, s) in self.stats.samples() {
            all.insert(name, s);
        }
        for (name, s) in self.registry.samples() {
            all.insert(name, s);
        }
        telemetry::encode::render(&all.into_iter().collect::<Vec<_>>())
    }

    /// Readiness verdict plus its JSON body: ready iff the registry has
    /// at least one resident model, the batch queue is under half its
    /// capacity, the worker pool is fully alive, and no shutdown has
    /// been requested. The body reports every check so an operator can
    /// see *which* gate failed from the 503 alone.
    pub fn readiness(&self) -> (bool, String) {
        let warm = !self.registry.is_empty();
        let depth = self.batcher.queue_depth();
        let cap = self.batcher.queue_cap();
        let queue_ok = depth * 2 < cap;
        let workers_ok = self.batcher.workers_alive();
        let shutting_down = self.is_shutdown();
        let ready = warm && queue_ok && workers_ok && !shutting_down;
        let body = Json::obj(vec![
            ("ready", Json::Bool(ready)),
            ("registry_warm", Json::Bool(warm)),
            ("queue_ok", Json::Bool(queue_ok)),
            ("queue_depth", Json::Num(depth as f64)),
            ("queue_cap", Json::Num(cap as f64)),
            ("workers_alive", Json::Bool(workers_ok)),
            ("shutting_down", Json::Bool(shutting_down)),
        ]).to_string();
        (ready, body + "\n")
    }

    /// Minimal HTTP reply for a plain `GET` on the TCP front: the
    /// metrics exposition on `/metrics` (or `/`), liveness on
    /// `/healthz`, readiness on `/readyz` (503 + per-check JSON when
    /// unready), 404 otherwise.
    fn http_scrape(&self, path: &str) -> String {
        const PROM: &str = "text/plain; version=0.0.4; charset=utf-8";
        const TEXT: &str = "text/plain; charset=utf-8";
        let (status, ctype, body) = match path {
            "/metrics" | "/" => ("200 OK", PROM, self.metrics_text()),
            "/healthz" => ("200 OK", TEXT, "ok\n".to_string()),
            "/readyz" => {
                let (ready, body) = self.readiness();
                (if ready { "200 OK" } else { "503 Service Unavailable" },
                 "application/json; charset=utf-8", body)
            }
            _ => ("404 Not Found", TEXT,
                  "scrape /metrics, /healthz or /readyz\n".to_string()),
        };
        format!(
            "HTTP/1.0 {status}\r\n\
             Content-Type: {ctype}\r\n\
             Content-Length: {}\r\n\
             Connection: close\r\n\r\n{body}",
            body.len())
    }

    /// Parse-handle-serialize one wire line, without tracing (kept for
    /// in-process callers and tests that want the bare protocol).
    pub fn handle_line(&self, line: &str) -> Response {
        match Request::parse_line(line) {
            Ok(req) => self.handle(req),
            Err(e) => Response::err(format!("bad request: {e:#}")),
        }
    }

    /// Answer one wire line with full request tracing — what both fronts
    /// run. Parses request + [`ReqMeta`], assigns a trace id when the
    /// client didn't send one, records the parse/validate/encode phase
    /// histograms, emits `slow_request` events past the `--slow-ms`
    /// threshold, and decorates the response with `trace_id`/`timing`
    /// when asked. Decoration only adds keys: payload fields are
    /// byte-identical to the untraced [`Server::handle_line`] path.
    pub fn answer_line(&self, line: &str) -> String {
        let t0 = Instant::now();
        let parsed = Json::parse(line).and_then(|j| {
            let meta = ReqMeta::from_json(&j)?;
            let req = Request::from_json(&j)?;
            Ok((req, meta))
        });
        let parse_us = t0.elapsed().as_micros() as u64;
        self.stats.record_phase(phase::PARSE, parse_us);
        let (req, meta) = match parsed {
            Ok(ok) => ok,
            Err(e) => {
                self.note_error();
                return Response::err(format!("bad request: {e:#}"))
                    .to_line();
            }
        };
        let assigned;
        let trace_id: &str = match &meta.trace_id {
            Some(t) => t,
            None => {
                assigned = format!(
                    "srv-{}", self.req_seq.fetch_add(1, Ordering::Relaxed));
                &assigned
            }
        };
        let (resp, ht) = self.handle_traced(req, trace_id);
        let total_us = t0.elapsed().as_micros() as u64;
        if self.slow_us.is_some_and(|limit| total_us > limit) {
            events::emit(Level::Warn, "slow_request", vec![
                ("trace_id", Json::Str(trace_id.to_string())),
                ("total_us", Json::Num(total_us as f64)),
                ("queue_wait_us", Json::Num(ht.batch.queue_wait_us as f64)),
                ("execute_us", Json::Num(ht.batch.execute_us as f64)),
            ]);
        }
        let timing = meta.timing.then(|| Timing {
            parse_us,
            validate_us: ht.validate_us,
            queue_wait_us: ht.batch.queue_wait_us,
            batch_assembly_us: ht.batch.assembly_us,
            execute_us: ht.batch.execute_us,
            total_us,
            batch_jobs: ht.batch.batch_jobs,
            batch_rows: ht.batch.batch_rows,
        });
        // echo the trace id iff the client supplied one or asked for
        // timing — plain requests get plain responses, byte for byte
        let echo = (meta.trace_id.is_some() || meta.timing)
            .then_some(trace_id);
        let t_enc = Instant::now();
        let out = decorate(resp.to_json(), echo, timing.as_ref()).to_string();
        self.stats.record_phase(
            phase::ENCODE, t_enc.elapsed().as_micros() as u64);
        out
    }

    // ------------------------------------------------------------------
    // Fronts
    // ------------------------------------------------------------------

    /// JSON-lines loop over arbitrary streams (the `--stdio` front; also
    /// what tests and CI drive). Blank lines are skipped; the loop ends at
    /// EOF or after answering `shutdown`.
    pub fn serve_stdio(&self, input: impl BufRead, mut out: impl Write)
                       -> Result<()> {
        for line in input.lines() {
            let line = line.context("reading request line")?;
            if line.trim().is_empty() {
                continue;
            }
            let reply = self.answer_line(&line);
            writeln!(out, "{reply}")?;
            out.flush()?;
            if self.is_shutdown() {
                break;
            }
        }
        Ok(())
    }

    /// Accept loopback JSON-lines connections until some client sends
    /// `shutdown`. One thread per connection; in-flight connections finish
    /// their current request before the listener returns.
    pub fn serve_tcp(&self, listener: TcpListener) -> Result<()> {
        listener.set_nonblocking(true)
            .context("listener nonblocking")?;
        std::thread::scope(|scope| -> Result<()> {
            loop {
                if self.is_shutdown() {
                    return Ok(());
                }
                match listener.accept() {
                    Ok((stream, _addr)) => {
                        scope.spawn(move || {
                            if let Err(e) = self.serve_conn(stream) {
                                eprintln!("serve: connection error: {e:#}");
                            }
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(e) => return Err(e).context("accept"),
                }
            }
        })
    }

    /// One JSON-lines TCP session. The read side uses a short timeout so
    /// idle connections notice a server-wide shutdown and exit instead of
    /// pinning the listener's scope forever.
    fn serve_conn(&self, stream: TcpStream) -> Result<()> {
        stream.set_read_timeout(Some(Duration::from_millis(100)))
            .context("read timeout")?;
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut writer = stream;
        let mut buf = String::new();
        loop {
            match reader.read_line(&mut buf) {
                Ok(0) => return Ok(()), // client closed
                Ok(_) => {
                    let line = buf.trim_end().to_string();
                    if let Some(rest) = line.strip_prefix("GET ") {
                        // plain HTTP scrape: answer and close (the
                        // Connection: close contract lets curl and
                        // Prometheus treat us as a one-shot endpoint)
                        let path = rest.split_whitespace().next()
                            .unwrap_or("");
                        writer.write_all(
                            self.http_scrape(path).as_bytes())?;
                        writer.flush()?;
                        return Ok(());
                    }
                    if !line.trim().is_empty() {
                        let reply = self.answer_line(&line);
                        writeln!(writer, "{reply}")?;
                        writer.flush()?;
                    }
                    buf.clear();
                    if self.is_shutdown() {
                        return Ok(());
                    }
                }
                Err(e) if matches!(e.kind(),
                                   std::io::ErrorKind::WouldBlock
                                   | std::io::ErrorKind::TimedOut) => {
                    // keep any partial line in `buf` and poll shutdown
                    if self.is_shutdown() {
                        return Ok(());
                    }
                }
                Err(e) => return Err(e).context("reading request"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Engine;
    use crate::tensor::Tensor;

    fn server() -> Server {
        let registry = Registry::new(Engine::native().unwrap(), 4);
        registry.register_untrained("realnvp2d", 3).unwrap();
        Server::new(registry, BatchConfig {
            max_delay: Duration::from_micros(200),
            ..BatchConfig::default()
        }).allow_untrained()
    }

    #[test]
    fn untrained_models_are_refused_by_default() {
        let registry = Registry::new(Engine::native().unwrap(), 4);
        registry.register_untrained("realnvp2d", 3).unwrap();
        let s = Server::new(registry, BatchConfig::default());
        let resp = s.handle(Request::Sample {
            model: None, n: 1, temperature: 1.0, seed: 0, cond: None,
        });
        let Response::Error { error } = resp else {
            panic!("expected refusal, got {resp:?}")
        };
        assert!(error.contains("untrained"), "{error}");
    }

    #[test]
    fn handle_answers_sample_score_stats_shutdown() {
        let s = server();
        let Response::Sample { x } = s.handle(Request::Sample {
            model: None, n: 3, temperature: 1.0, seed: 7, cond: None,
        }) else { panic!("sample failed") };
        assert_eq!(x.shape, vec![3, 2]);

        let Response::Score { log_density } = s.handle(Request::Score {
            model: None, x, cond: None,
        }) else { panic!("score failed") };
        assert_eq!(log_density.len(), 3);
        assert!(log_density.iter().all(|v| v.is_finite()));

        let Response::Stats(snap) = s.handle(Request::Stats) else {
            panic!("stats failed")
        };
        assert_eq!(snap.requests, 2);
        assert_eq!(snap.models, 1);

        assert_eq!(s.handle(Request::Shutdown), Response::Shutdown);
        assert!(s.is_shutdown());
    }

    #[test]
    fn metrics_op_covers_batcher_registry_and_per_op_series() {
        let s = server();
        let Response::Sample { x } = s.handle(Request::Sample {
            model: None, n: 2, temperature: 1.0, seed: 4, cond: None,
        }) else { panic!("sample failed") };
        let _ = s.handle(Request::Score { model: None, x, cond: None });

        let Response::Metrics { text } = s.handle(Request::Metrics) else {
            panic!("metrics op failed")
        };
        let fams = telemetry::encode::parse_exposition(&text).unwrap();
        let names: Vec<&str> =
            fams.iter().map(|f| f.name.as_str()).collect();
        for required in [
            "invertnet_serve_requests_total",
            "invertnet_serve_batches_total",
            "invertnet_serve_errors_total",
            "invertnet_serve_queue_depth",
            "invertnet_serve_batch_rows",
            "invertnet_serve_sample_latency_us",
            "invertnet_serve_score_latency_us",
            "invertnet_serve_phase_queue_wait_us",
            "invertnet_serve_phase_execute_us",
            "invertnet_serve_model_requests_total",
            "invertnet_serve_model_rows_total",
            "invertnet_registry_loads_total",
            "invertnet_registry_evictions_total",
            "invertnet_registry_rejects_total",
        ] {
            assert!(names.contains(&required),
                    "metrics text is missing {required}: {names:?}");
        }
        // the two answered requests must be visible in the text
        assert!(text.contains("invertnet_serve_requests_total 2"),
                "{text}");
        // ...and attributed to the model that served them
        assert!(text.contains(
            "invertnet_serve_model_requests_total{model=\"realnvp2d\"} 2"),
                "{text}");
    }

    #[test]
    fn get_scrape_answers_minimal_http() {
        let s = server();
        let resp = s.http_scrape("/metrics");
        assert!(resp.starts_with("HTTP/1.0 200 OK\r\n"), "{resp}");
        assert!(resp.contains("Content-Type: text/plain"), "{resp}");
        // one-shot endpoint contract: the scrape reply must close the
        // connection and say so
        assert!(resp.contains("Connection: close\r\n"), "{resp}");
        let body = resp.split("\r\n\r\n").nth(1).unwrap();
        let len: usize = resp.lines()
            .find_map(|l| l.strip_prefix("Content-Length: "))
            .unwrap().trim().parse().unwrap();
        assert_eq!(body.len(), len);
        telemetry::encode::parse_exposition(body).unwrap();
        assert!(s.http_scrape("/nope").starts_with("HTTP/1.0 404"),
                "unknown paths must 404");
    }

    #[test]
    fn health_surfaces_report_liveness_and_readiness() {
        let s = server();
        let live = s.http_scrape("/healthz");
        assert!(live.starts_with("HTTP/1.0 200 OK\r\n"), "{live}");
        assert!(live.ends_with("ok\n"), "{live}");

        // warm registry + empty queue + live workers => ready
        let ready = s.http_scrape("/readyz");
        assert!(ready.starts_with("HTTP/1.0 200 OK\r\n"), "{ready}");
        let body = ready.split("\r\n\r\n").nth(1).unwrap();
        let j = Json::parse(body).unwrap();
        assert_eq!(j.req("ready").unwrap(), &Json::Bool(true), "{body}");

        // an empty registry is not ready (and says which check failed)
        let cold = Server::new(
            Registry::new(Engine::native().unwrap(), 4),
            BatchConfig::default());
        let resp = cold.http_scrape("/readyz");
        assert!(resp.starts_with("HTTP/1.0 503"), "{resp}");
        let body = resp.split("\r\n\r\n").nth(1).unwrap();
        let j = Json::parse(body).unwrap();
        assert_eq!(j.req("ready").unwrap(), &Json::Bool(false), "{body}");
        assert_eq!(j.req("registry_warm").unwrap(), &Json::Bool(false));
        assert_eq!(j.req("workers_alive").unwrap(), &Json::Bool(true));

        // shutdown flips readiness (liveness stays up for the drain)
        s.handle(Request::Shutdown);
        assert!(s.http_scrape("/readyz").starts_with("HTTP/1.0 503"));
        assert!(s.http_scrape("/healthz").starts_with("HTTP/1.0 200"));
    }

    /// The readyz queue gate, deterministically: one worker, a huge
    /// coalescing window, and max_batch == queue_cap == 100 means 50
    /// queued single-row jobs *cannot* fire (the group is neither full
    /// nor past its deadline), so depth sits at exactly 50 — at half
    /// capacity, unready. Filling the group to 100 fires it, the queue
    /// drains, and readiness comes back.
    #[test]
    fn readyz_flips_under_queue_saturation_and_recovers() {
        let registry = Registry::new(Engine::native().unwrap(), 4);
        registry.register_untrained("realnvp2d", 3).unwrap();
        let s = Server::new(registry, BatchConfig {
            max_batch: 100,
            max_delay: Duration::from_secs(60),
            workers: 1,
            queue_cap: 100,
        }).allow_untrained();
        let (ready, body) = s.readiness();
        assert!(ready, "{body}");

        let m = s.registry.get(None).unwrap();
        let job = || Work::Score { x: Tensor::zeros(&[1, 2]), cond: None };
        let mut rxs = Vec::new();
        for _ in 0..50 {
            rxs.push(s.batcher.submit(m.clone(), job()).unwrap());
        }
        let (ready, body) = s.readiness();
        assert!(!ready, "50/100 queued must be unready: {body}");
        assert!(body.contains("\"queue_ok\":false"), "{body}");
        assert!(s.http_scrape("/readyz").starts_with("HTTP/1.0 503"));

        for _ in 0..50 {
            rxs.push(s.batcher.submit(m.clone(), job()).unwrap());
        }
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let (ready, body) = s.readiness();
        assert!(ready, "drained queue must be ready again: {body}");
        assert!(s.http_scrape("/readyz").starts_with("HTTP/1.0 200"));
    }

    #[test]
    fn answer_line_echoes_trace_id_and_timing_on_request() {
        let s = server();
        // plain requests get plain responses: no extras
        let line = s.answer_line(r#"{"op":"stats"}"#);
        let j = Json::parse(&line).unwrap();
        assert!(j.get("trace_id").is_none(), "{line}");
        assert!(j.get("timing").is_none(), "{line}");

        // a client-supplied trace id is echoed verbatim
        let line = s.answer_line(
            r#"{"op":"sample","n":2,"seed":3,"trace_id":"req-abc-123"}"#);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.req("trace_id").unwrap().as_str().unwrap(),
                   "req-abc-123", "{line}");
        assert!(j.get("timing").is_none(), "{line}");
        assert!(matches!(Response::parse_line(&line).unwrap(),
                         Response::Sample { .. }));

        // timing:true gets the phase block and a server-assigned id
        let line = s.answer_line(
            r#"{"op":"sample","n":2,"seed":3,"timing":true}"#);
        let j = Json::parse(&line).unwrap();
        let tid = j.req("trace_id").unwrap().as_str().unwrap();
        assert!(tid.starts_with("srv-"), "{tid}");
        let t = j.req("timing").unwrap();
        for key in ["parse_us", "validate_us", "queue_wait_us",
                    "batch_assembly_us", "execute_us", "total_us",
                    "batch_jobs", "batch_rows"] {
            assert!(t.get(key).is_some(), "timing missing {key}: {line}");
        }
        assert_eq!(t.req("batch_jobs").unwrap(), &Json::Num(1.0), "{line}");
        assert_eq!(t.req("batch_rows").unwrap(), &Json::Num(2.0), "{line}");
        assert!(matches!(Response::parse_line(&line).unwrap(),
                         Response::Sample { .. }));

        // a bad trace id is a protocol error, not a silent drop
        let line = s.answer_line(r#"{"op":"stats","trace_id":""}"#);
        assert!(Response::parse_line(&line).unwrap().is_error(), "{line}");
    }

    #[test]
    fn debug_dump_op_returns_flight_recorder_report() {
        let s = server();
        let _ = s.handle(Request::Sample {
            model: None, n: 1, temperature: 1.0, seed: 1, cond: None,
        });
        let Response::DebugDump { report } = s.handle(Request::DebugDump)
        else { panic!("debug-dump failed") };
        assert_eq!(report.req("schema").unwrap().as_str().unwrap(),
                   events::DUMP_SCHEMA);
        assert!(matches!(report.req("events").unwrap(), Json::Arr(_)));
        assert_eq!(report.req("requests_total").unwrap(), &Json::Num(1.0));
        assert_eq!(report.req("reason").unwrap().as_str().unwrap(),
                   "debug-dump op");
        // and it survives the wire roundtrip
        let line = s.answer_line(r#"{"op":"debug-dump"}"#);
        let Response::DebugDump { report } =
            Response::parse_line(&line).unwrap()
        else { panic!("wire debug-dump failed: {line}") };
        assert_eq!(report.req("schema").unwrap().as_str().unwrap(),
                   events::DUMP_SCHEMA);
    }

    #[test]
    fn bad_lines_become_error_responses_not_crashes() {
        let s = server();
        assert!(s.handle_line("{{{").is_error());
        assert!(s.handle_line(r#"{"op":"warp"}"#).is_error());
        let resp = s.handle(Request::Score {
            model: None,
            x: Tensor::zeros(&[2, 9]), // wrong feature width
            cond: None,
        });
        assert!(resp.is_error(), "{resp:?}");
    }

    #[test]
    fn posterior_op_is_bit_identical_to_the_analysis_path() {
        let registry = Registry::new(Engine::native().unwrap(), 4);
        registry.register_untrained("cond_lingauss2d", 5).unwrap();
        let s = Server::new(registry, BatchConfig {
            max_delay: Duration::from_micros(200),
            ..BatchConfig::default()
        }).allow_untrained();

        let y = vec![0.7f32, -0.4];
        let resp = s.handle(Request::Posterior {
            model: None, y: y.clone(), n: 16, temperature: 1.0, seed: 9,
            return_samples: true,
        });
        let Response::Posterior { n, mean, std, samples } = resp else {
            panic!("posterior failed: {resp:?}")
        };
        assert_eq!(n, 16);

        let m = s.registry().get(None).unwrap();
        let direct = analysis::posterior_samples(
            &m.flow, &m.params, &y, 16, 1.0, 9).unwrap();
        let direct_sum = analysis::summarize(&direct);
        let got = samples.expect("samples were requested");
        assert_eq!(got.shape, direct.shape);
        for (a, b) in got.data.iter().zip(&direct.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "sample bits differ");
        }
        for (a, b) in mean.iter().zip(&direct_sum.mean) {
            assert_eq!(a.to_bits(), b.to_bits(), "mean bits differ");
        }
        for (a, b) in std.iter().zip(&direct_sum.std) {
            assert_eq!(a.to_bits(), b.to_bits(), "std bits differ");
        }
    }

    #[test]
    fn posterior_op_rejects_unconditional_models_and_bad_y() {
        let s = server(); // realnvp2d: no cond
        let resp = s.handle(Request::Posterior {
            model: None, y: vec![0.1, 0.2], n: 4, temperature: 1.0,
            seed: 0, return_samples: false,
        });
        assert!(resp.is_error(), "{resp:?}");

        let registry = Registry::new(Engine::native().unwrap(), 4);
        registry.register_untrained("cond_lingauss2d", 5).unwrap();
        let s = Server::new(registry, BatchConfig::default())
            .allow_untrained();
        // y width 3 != dcond 2
        let resp = s.handle(Request::Posterior {
            model: None, y: vec![0.1, 0.2, 0.3], n: 4, temperature: 1.0,
            seed: 0, return_samples: false,
        });
        assert!(resp.is_error(), "{resp:?}");
    }

    #[test]
    fn stdio_session_runs_to_shutdown() {
        let s = server();
        let session = concat!(
            r#"{"op":"sample","n":2,"seed":1}"#, "\n",
            "\n", // blank lines are skipped
            r#"{"op":"stats"}"#, "\n",
            r#"{"op":"shutdown"}"#, "\n",
            r#"{"op":"never-reached"}"#, "\n",
        );
        let mut out = Vec::new();
        s.serve_stdio(session.as_bytes(), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3, "{text}");
        assert!(matches!(Response::parse_line(lines[0]).unwrap(),
                         Response::Sample { .. }));
        assert!(matches!(Response::parse_line(lines[1]).unwrap(),
                         Response::Stats(_)));
        assert_eq!(Response::parse_line(lines[2]).unwrap(),
                   Response::Shutdown);
    }
}
