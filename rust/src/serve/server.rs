//! The request core: [`Server::handle`] maps one [`Request`] to one
//! [`Response`], independent of transport. Two fronts wrap it:
//!
//! * [`Server::serve_stdio`] — a read-line/write-line loop over any
//!   `BufRead`/`Write` pair, which is how tests and the CI smoke drive a
//!   full serving session hermetically;
//! * [`Server::serve_tcp`] — a JSON-lines loopback TCP listener with one
//!   lightweight thread per connection.
//!
//! Both exit after a `shutdown` request (in-flight work drains first).
//!
//! The TCP front additionally answers plain `GET /metrics` lines
//! (`curl http://127.0.0.1:7878/metrics`) with a minimal HTTP response
//! carrying the same Prometheus text exposition as the JSON `metrics`
//! op, so a stock Prometheus scraper needs no protocol adapter.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::posterior::analysis;
use crate::telemetry;
use crate::util::rng::Pcg64;

use super::batcher::{BatchConfig, Batcher, Reply, ServeStats, Work};
use super::protocol::{Request, Response};
use super::registry::{Registry, ServedModel};

/// Per-request conditioning check, run before a job may enter the batch
/// queue: a request with a missing/extra/mis-shaped cond fails alone
/// instead of erroring the whole coalesced pass it would have joined.
fn check_cond_request(m: &ServedModel, rows: usize, cond: Option<&crate::Tensor>)
                      -> Result<()> {
    match (&m.flow.def.cond_shape, cond) {
        (None, None) => Ok(()),
        (None, Some(_)) => {
            anyhow::bail!("network {} takes no cond", m.name)
        }
        (Some(_), None) => {
            anyhow::bail!("network {} requires a cond tensor with {rows} \
                           row(s)", m.name)
        }
        (Some(shape), Some(c)) => {
            if c.shape.len() != shape.len()
                || c.shape[1..] != shape[1..]
                || c.batch() != rows
            {
                anyhow::bail!(
                    "cond shape {:?} does not match network {} cond \
                     per-sample shape {:?} with {rows} row(s)",
                    c.shape, m.name, &shape[1..]);
            }
            Ok(())
        }
    }
}

/// A long-lived inference service over a model [`Registry`].
pub struct Server {
    registry: Arc<Registry>,
    batcher: Batcher,
    stats: Arc<ServeStats>,
    shutdown: AtomicBool,
    /// Allow serving models whose weights are a random init (off by
    /// default so a missing checkpoint cannot silently serve noise).
    allow_untrained: bool,
}

impl Server {
    pub fn new(registry: Registry, cfg: BatchConfig) -> Server {
        let stats = Arc::new(ServeStats::default());
        Server {
            registry: Arc::new(registry),
            batcher: Batcher::new(cfg, stats.clone()),
            stats,
            shutdown: AtomicBool::new(false),
            allow_untrained: false,
        }
    }

    /// Opt in to serving untrained (randomly initialized) models.
    pub fn allow_untrained(mut self) -> Server {
        self.allow_untrained = true;
        self
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::Relaxed)
    }

    // ------------------------------------------------------------------
    // Transport-agnostic core
    // ------------------------------------------------------------------

    /// Answer one request. Never panics on bad input — protocol and
    /// execution errors come back as [`Response::Error`].
    pub fn handle(&self, req: Request) -> Response {
        match self.try_handle(req) {
            Ok(resp) => resp,
            Err(e) => Response::err(format!("{e:#}")),
        }
    }

    fn try_handle(&self, req: Request) -> Result<Response> {
        match req {
            Request::Sample { model, n, temperature, seed, cond } => {
                let m = self.model(model.as_deref())?;
                // validate BEFORE queueing: a bad request must fail alone,
                // never poison the valid requests it would coalesce with
                check_cond_request(&m, n, cond.as_ref())?;
                // each request draws its own latents from its own seed, so
                // the reply is bit-identical to a direct
                // `sample_batch(&params, n, cond, T, &mut Pcg64::new(seed))`
                // no matter what it batches with
                let latents = m.flow.sample_latents(
                    n, temperature, &mut Pcg64::new(seed))?;
                let rx = self.batcher.submit(
                    m, Work::Sample { latents, cond })?;
                match rx.recv().context("serve worker hung up")?? {
                    Reply::Samples(x) => Ok(Response::Sample { x }),
                    Reply::Scores(_) => unreachable!("sample got scores"),
                }
            }
            Request::Score { model, x, cond } => {
                let m = self.model(model.as_deref())?;
                let want = &m.flow.def.in_shape;
                if x.batch() == 0 {
                    anyhow::bail!("score x has no rows");
                }
                if x.shape.len() != want.len() || x.shape[1..] != want[1..] {
                    anyhow::bail!(
                        "score x shape {:?} does not match network {} \
                         per-sample shape {:?}",
                        x.shape, m.name, &want[1..]);
                }
                check_cond_request(&m, x.batch(), cond.as_ref())?;
                let rx = self.batcher.submit(m, Work::Score { x, cond })?;
                match rx.recv().context("serve worker hung up")?? {
                    Reply::Scores(log_density) => {
                        Ok(Response::Score { log_density })
                    }
                    Reply::Samples(_) => unreachable!("score got samples"),
                }
            }
            Request::Posterior { model, y, n, temperature, seed,
                                 return_samples } => {
                let m = self.model(model.as_deref())?;
                // tile the observation across the conditioning batch and
                // validate it exactly like a sample request, BEFORE
                // queueing (a bad y must fail alone, not poison a batch)
                let cond = analysis::tile_observation(&y, n)?;
                check_cond_request(&m, n, Some(&cond))?;
                // same generator as analysis::posterior_samples, so the
                // reply is bit-identical to the in-process call no matter
                // what this job coalesces with
                let latents = m.flow.sample_latents(
                    n, temperature, &mut Pcg64::new(seed))?;
                let rx = self.batcher.submit(
                    m, Work::Sample { latents, cond: Some(cond) })?;
                match rx.recv().context("serve worker hung up")?? {
                    Reply::Samples(x) => {
                        let s = analysis::summarize(&x);
                        Ok(Response::Posterior {
                            n,
                            mean: s.mean,
                            std: s.std,
                            samples: return_samples.then_some(x),
                        })
                    }
                    Reply::Scores(_) => unreachable!("posterior got scores"),
                }
            }
            Request::Stats => Ok(Response::Stats(self.stats.snapshot(
                self.batcher.queue_depth() as u64,
                self.registry.len() as u64,
            ))),
            Request::Metrics => Ok(Response::Metrics {
                text: self.metrics_text(),
            }),
            Request::Shutdown => {
                self.shutdown.store(true, Ordering::Relaxed);
                Ok(Response::Shutdown)
            }
        }
    }

    fn model(&self, name: Option<&str>)
             -> Result<Arc<ServedModel>> {
        let m = self.registry.get(name)?;
        if !m.trained && !self.allow_untrained {
            anyhow::bail!(
                "model {:?} has untrained (randomly initialized) weights; \
                 load a checkpoint or start the server with untrained \
                 models explicitly allowed", m.name);
        }
        Ok(m)
    }

    /// Full telemetry scrape: the process-global registry (span
    /// histograms, train/scratch series if this process also trains)
    /// merged with the serve-local instruments embedded in `ServeStats`
    /// and the model registry, rendered as Prometheus text exposition.
    pub fn metrics_text(&self) -> String {
        // refresh the point-in-time gauges before sampling them
        let _ = self.stats.snapshot(self.batcher.queue_depth() as u64,
                                    self.registry.len() as u64);
        let mut all: std::collections::BTreeMap<String, telemetry::Sample> =
            telemetry::global().snapshot().into_iter().collect();
        for (name, s) in self.stats.samples() {
            all.insert(name, s);
        }
        for (name, s) in self.registry.samples() {
            all.insert(name, s);
        }
        telemetry::encode::render(&all.into_iter().collect::<Vec<_>>())
    }

    /// Minimal HTTP reply for a plain `GET` on the TCP front: the
    /// metrics exposition on `/metrics` (or `/`), 404 otherwise.
    fn http_scrape(&self, path: &str) -> String {
        let (status, body) = if path == "/metrics" || path == "/" {
            ("200 OK", self.metrics_text())
        } else {
            ("404 Not Found", "scrape /metrics\n".to_string())
        };
        format!(
            "HTTP/1.0 {status}\r\n\
             Content-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
             Content-Length: {}\r\n\
             Connection: close\r\n\r\n{body}",
            body.len())
    }

    /// Parse-handle-serialize one wire line.
    pub fn handle_line(&self, line: &str) -> Response {
        match Request::parse_line(line) {
            Ok(req) => self.handle(req),
            Err(e) => Response::err(format!("bad request: {e:#}")),
        }
    }

    // ------------------------------------------------------------------
    // Fronts
    // ------------------------------------------------------------------

    /// JSON-lines loop over arbitrary streams (the `--stdio` front; also
    /// what tests and CI drive). Blank lines are skipped; the loop ends at
    /// EOF or after answering `shutdown`.
    pub fn serve_stdio(&self, input: impl BufRead, mut out: impl Write)
                       -> Result<()> {
        for line in input.lines() {
            let line = line.context("reading request line")?;
            if line.trim().is_empty() {
                continue;
            }
            let resp = self.handle_line(&line);
            writeln!(out, "{}", resp.to_line())?;
            out.flush()?;
            if self.is_shutdown() {
                break;
            }
        }
        Ok(())
    }

    /// Accept loopback JSON-lines connections until some client sends
    /// `shutdown`. One thread per connection; in-flight connections finish
    /// their current request before the listener returns.
    pub fn serve_tcp(&self, listener: TcpListener) -> Result<()> {
        listener.set_nonblocking(true)
            .context("listener nonblocking")?;
        std::thread::scope(|scope| -> Result<()> {
            loop {
                if self.is_shutdown() {
                    return Ok(());
                }
                match listener.accept() {
                    Ok((stream, _addr)) => {
                        scope.spawn(move || {
                            if let Err(e) = self.serve_conn(stream) {
                                eprintln!("serve: connection error: {e:#}");
                            }
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(e) => return Err(e).context("accept"),
                }
            }
        })
    }

    /// One JSON-lines TCP session. The read side uses a short timeout so
    /// idle connections notice a server-wide shutdown and exit instead of
    /// pinning the listener's scope forever.
    fn serve_conn(&self, stream: TcpStream) -> Result<()> {
        stream.set_read_timeout(Some(Duration::from_millis(100)))
            .context("read timeout")?;
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut writer = stream;
        let mut buf = String::new();
        loop {
            match reader.read_line(&mut buf) {
                Ok(0) => return Ok(()), // client closed
                Ok(_) => {
                    let line = buf.trim_end().to_string();
                    if let Some(rest) = line.strip_prefix("GET ") {
                        // plain HTTP scrape: answer and close (the
                        // Connection: close contract lets curl and
                        // Prometheus treat us as a one-shot endpoint)
                        let path = rest.split_whitespace().next()
                            .unwrap_or("");
                        writer.write_all(
                            self.http_scrape(path).as_bytes())?;
                        writer.flush()?;
                        return Ok(());
                    }
                    if !line.trim().is_empty() {
                        let resp = self.handle_line(&line);
                        writeln!(writer, "{}", resp.to_line())?;
                        writer.flush()?;
                    }
                    buf.clear();
                    if self.is_shutdown() {
                        return Ok(());
                    }
                }
                Err(e) if matches!(e.kind(),
                                   std::io::ErrorKind::WouldBlock
                                   | std::io::ErrorKind::TimedOut) => {
                    // keep any partial line in `buf` and poll shutdown
                    if self.is_shutdown() {
                        return Ok(());
                    }
                }
                Err(e) => return Err(e).context("reading request"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Engine;
    use crate::tensor::Tensor;

    fn server() -> Server {
        let registry = Registry::new(Engine::native().unwrap(), 4);
        registry.register_untrained("realnvp2d", 3).unwrap();
        Server::new(registry, BatchConfig {
            max_delay: Duration::from_micros(200),
            ..BatchConfig::default()
        }).allow_untrained()
    }

    #[test]
    fn untrained_models_are_refused_by_default() {
        let registry = Registry::new(Engine::native().unwrap(), 4);
        registry.register_untrained("realnvp2d", 3).unwrap();
        let s = Server::new(registry, BatchConfig::default());
        let resp = s.handle(Request::Sample {
            model: None, n: 1, temperature: 1.0, seed: 0, cond: None,
        });
        let Response::Error { error } = resp else {
            panic!("expected refusal, got {resp:?}")
        };
        assert!(error.contains("untrained"), "{error}");
    }

    #[test]
    fn handle_answers_sample_score_stats_shutdown() {
        let s = server();
        let Response::Sample { x } = s.handle(Request::Sample {
            model: None, n: 3, temperature: 1.0, seed: 7, cond: None,
        }) else { panic!("sample failed") };
        assert_eq!(x.shape, vec![3, 2]);

        let Response::Score { log_density } = s.handle(Request::Score {
            model: None, x, cond: None,
        }) else { panic!("score failed") };
        assert_eq!(log_density.len(), 3);
        assert!(log_density.iter().all(|v| v.is_finite()));

        let Response::Stats(snap) = s.handle(Request::Stats) else {
            panic!("stats failed")
        };
        assert_eq!(snap.requests, 2);
        assert_eq!(snap.models, 1);

        assert_eq!(s.handle(Request::Shutdown), Response::Shutdown);
        assert!(s.is_shutdown());
    }

    #[test]
    fn metrics_op_covers_batcher_registry_and_per_op_series() {
        let s = server();
        let Response::Sample { x } = s.handle(Request::Sample {
            model: None, n: 2, temperature: 1.0, seed: 4, cond: None,
        }) else { panic!("sample failed") };
        let _ = s.handle(Request::Score { model: None, x, cond: None });

        let Response::Metrics { text } = s.handle(Request::Metrics) else {
            panic!("metrics op failed")
        };
        let fams = telemetry::encode::parse_exposition(&text).unwrap();
        let names: Vec<&str> =
            fams.iter().map(|f| f.name.as_str()).collect();
        for required in [
            "invertnet_serve_requests_total",
            "invertnet_serve_batches_total",
            "invertnet_serve_errors_total",
            "invertnet_serve_queue_depth",
            "invertnet_serve_batch_rows",
            "invertnet_serve_sample_latency_us",
            "invertnet_serve_score_latency_us",
            "invertnet_registry_loads_total",
            "invertnet_registry_evictions_total",
            "invertnet_registry_rejects_total",
        ] {
            assert!(names.contains(&required),
                    "metrics text is missing {required}: {names:?}");
        }
        // the two answered requests must be visible in the text
        assert!(text.contains("invertnet_serve_requests_total 2"),
                "{text}");
    }

    #[test]
    fn get_scrape_answers_minimal_http() {
        let s = server();
        let resp = s.http_scrape("/metrics");
        assert!(resp.starts_with("HTTP/1.0 200 OK\r\n"), "{resp}");
        assert!(resp.contains("Content-Type: text/plain"), "{resp}");
        let body = resp.split("\r\n\r\n").nth(1).unwrap();
        let len: usize = resp.lines()
            .find_map(|l| l.strip_prefix("Content-Length: "))
            .unwrap().trim().parse().unwrap();
        assert_eq!(body.len(), len);
        telemetry::encode::parse_exposition(body).unwrap();
        assert!(s.http_scrape("/nope").starts_with("HTTP/1.0 404"),
                "unknown paths must 404");
    }

    #[test]
    fn bad_lines_become_error_responses_not_crashes() {
        let s = server();
        assert!(s.handle_line("{{{").is_error());
        assert!(s.handle_line(r#"{"op":"warp"}"#).is_error());
        let resp = s.handle(Request::Score {
            model: None,
            x: Tensor::zeros(&[2, 9]), // wrong feature width
            cond: None,
        });
        assert!(resp.is_error(), "{resp:?}");
    }

    #[test]
    fn posterior_op_is_bit_identical_to_the_analysis_path() {
        let registry = Registry::new(Engine::native().unwrap(), 4);
        registry.register_untrained("cond_lingauss2d", 5).unwrap();
        let s = Server::new(registry, BatchConfig {
            max_delay: Duration::from_micros(200),
            ..BatchConfig::default()
        }).allow_untrained();

        let y = vec![0.7f32, -0.4];
        let resp = s.handle(Request::Posterior {
            model: None, y: y.clone(), n: 16, temperature: 1.0, seed: 9,
            return_samples: true,
        });
        let Response::Posterior { n, mean, std, samples } = resp else {
            panic!("posterior failed: {resp:?}")
        };
        assert_eq!(n, 16);

        let m = s.registry().get(None).unwrap();
        let direct = analysis::posterior_samples(
            &m.flow, &m.params, &y, 16, 1.0, 9).unwrap();
        let direct_sum = analysis::summarize(&direct);
        let got = samples.expect("samples were requested");
        assert_eq!(got.shape, direct.shape);
        for (a, b) in got.data.iter().zip(&direct.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "sample bits differ");
        }
        for (a, b) in mean.iter().zip(&direct_sum.mean) {
            assert_eq!(a.to_bits(), b.to_bits(), "mean bits differ");
        }
        for (a, b) in std.iter().zip(&direct_sum.std) {
            assert_eq!(a.to_bits(), b.to_bits(), "std bits differ");
        }
    }

    #[test]
    fn posterior_op_rejects_unconditional_models_and_bad_y() {
        let s = server(); // realnvp2d: no cond
        let resp = s.handle(Request::Posterior {
            model: None, y: vec![0.1, 0.2], n: 4, temperature: 1.0,
            seed: 0, return_samples: false,
        });
        assert!(resp.is_error(), "{resp:?}");

        let registry = Registry::new(Engine::native().unwrap(), 4);
        registry.register_untrained("cond_lingauss2d", 5).unwrap();
        let s = Server::new(registry, BatchConfig::default())
            .allow_untrained();
        // y width 3 != dcond 2
        let resp = s.handle(Request::Posterior {
            model: None, y: vec![0.1, 0.2, 0.3], n: 4, temperature: 1.0,
            seed: 0, return_samples: false,
        });
        assert!(resp.is_error(), "{resp:?}");
    }

    #[test]
    fn stdio_session_runs_to_shutdown() {
        let s = server();
        let session = concat!(
            r#"{"op":"sample","n":2,"seed":1}"#, "\n",
            "\n", // blank lines are skipped
            r#"{"op":"stats"}"#, "\n",
            r#"{"op":"shutdown"}"#, "\n",
            r#"{"op":"never-reached"}"#, "\n",
        );
        let mut out = Vec::new();
        s.serve_stdio(session.as_bytes(), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3, "{text}");
        assert!(matches!(Response::parse_line(lines[0]).unwrap(),
                         Response::Sample { .. }));
        assert!(matches!(Response::parse_line(lines[1]).unwrap(),
                         Response::Stats(_)));
        assert_eq!(Response::parse_line(lines[2]).unwrap(),
                   Response::Shutdown);
    }
}
