//! Request micro-batching: coalesce many single-client `sample`/`score`
//! requests into one batched inverse/forward pass.
//!
//! Every layer program is batch-elementwise, so a coalesced pass returns
//! each caller bits it could not tell apart from a private pass — batching
//! is invisible except in throughput. The scheduler:
//!
//! * coalesces jobs sharing a **group** (same model, same op) from the
//!   front of one FIFO queue;
//! * fires a batch when it reaches `max_batch` jobs *or* the oldest job's
//!   `max_delay` deadline passes, whichever is first;
//! * executes on a pool of worker threads, each forking the model's flow
//!   ([`crate::Flow::fork`]) so concurrent passes are metered on
//!   independent ledgers;
//! * applies backpressure through a bounded queue — `submit` blocks until
//!   space frees (or times out with an error), so a flood of clients
//!   degrades into queueing latency, not unbounded memory.
//!
//! Two thread pools compose here: `--workers` runs *passes* concurrently
//! (many small coalesced batches), while `--threads` (the engine's
//! inference pool, inherited by every forked flow) chunks *within* one
//! large pass — a single `posterior`/`sample` request for hundreds of
//! rows fans its inverse across the pool via [`crate::Flow::invert`]'s
//! relaxed-batch chunked path, bit-identically. Size
//! them jointly: `workers * threads` is the worst-case concurrent
//! backend parallelism.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::coordinator::InferOpts;
use crate::telemetry::events::{self, Level};
use crate::telemetry::{Counter, Gauge, Histogram, Sample};
use crate::tensor::ops::{concat_rows, slice_rows};
use crate::tensor::Tensor;
use crate::util::json::Json;

use super::protocol::StatsSnapshot;
use super::registry::ServedModel;

/// Scheduler knobs (CLI: `--max-batch`, `--max-delay-us`, `--workers`).
#[derive(Debug, Clone)]
pub struct BatchConfig {
    /// Most jobs coalesced into one pass (1 disables coalescing).
    pub max_batch: usize,
    /// How long the oldest queued job may wait for company.
    pub max_delay: Duration,
    /// Executor threads.
    pub workers: usize,
    /// Bound on queued jobs (backpressure); `submit` blocks when full.
    pub queue_cap: usize,
}

impl Default for BatchConfig {
    fn default() -> BatchConfig {
        BatchConfig {
            max_batch: 8,
            max_delay: Duration::from_micros(500),
            workers: 2,
            queue_cap: 1024,
        }
    }
}

/// One unit of batched work. `Sample` carries pre-drawn latents (each
/// request draws from its own seeded rng *before* queueing, so coalescing
/// cannot perturb anyone's randomness).
pub enum Work {
    Sample { latents: Vec<Tensor>, cond: Option<Tensor> },
    Score { x: Tensor, cond: Option<Tensor> },
}

impl Work {
    /// Rows this job contributes to a batched pass.
    fn rows(&self) -> usize {
        match self {
            Work::Sample { latents, .. } => {
                latents.first().map_or(0, |t| t.batch())
            }
            Work::Score { x, .. } => x.batch(),
        }
    }

    fn op_tag(&self) -> u8 {
        match self {
            Work::Sample { .. } => 0,
            Work::Score { .. } => 1,
        }
    }
}

/// What comes back: one batch row-slice per job.
pub enum ReplyPayload {
    Samples(Tensor),
    Scores(Vec<f32>),
}

/// Batch-side phase timings attached to every reply so the server can
/// assemble the request's `timing` block and feed the phase histograms.
/// `queue_wait_us` is per-job (enqueue → the worker taking its group);
/// `assembly_us`/`execute_us` are shared by every job of the batch.
#[derive(Debug, Clone, Copy, Default)]
pub struct BatchTimes {
    pub queue_wait_us: u64,
    pub assembly_us: u64,
    pub execute_us: u64,
    pub batch_jobs: u64,
    pub batch_rows: u64,
}

/// One coalesced answer: the payload slice plus its batch timings.
pub struct Reply {
    pub payload: ReplyPayload,
    pub times: BatchTimes,
}

struct Job {
    model: Arc<ServedModel>,
    work: Work,
    tx: Sender<Result<Reply>>,
    t_enq: Instant,
    /// Request trace id, carried front → queue → worker so events fired
    /// from the batch side can name the requests they served. Empty for
    /// untraced internal callers.
    trace_id: String,
}

/// Jobs batch together iff same resident model instance + same op.
fn group_of(j: &Job) -> (usize, u8) {
    (Arc::as_ptr(&j.model) as usize, j.work.op_tag())
}

// ---------------------------------------------------------------------------
// Serving metrics
// ---------------------------------------------------------------------------

/// Indices into [`ServeStats`]' per-phase histograms. One histogram per
/// request-lifecycle phase, exported as `invertnet_serve_phase_<p>_us`.
/// The server records `parse`/`validate`/`encode` (front-side), the
/// batcher records `queue_wait`/`batch_assembly`/`execute` (batch-side).
pub mod phase {
    pub const PARSE: usize = 0;
    pub const VALIDATE: usize = 1;
    pub const QUEUE_WAIT: usize = 2;
    pub const BATCH_ASSEMBLY: usize = 3;
    pub const EXECUTE: usize = 4;
    pub const ENCODE: usize = 5;
    pub const NAMES: [&str; 6] =
        ["parse", "validate", "queue_wait", "batch_assembly", "execute", "encode"];
}

/// Serving metrics on telemetry primitives: relaxed-atomic counters plus
/// per-op log2-bucket latency histograms. This replaced a bounded latency
/// ring that silently dropped samples under load and sorted a partial
/// window for percentiles — histogram bucket merges now answer
/// p50/p99/p99.9 over the whole serving history, per op and pooled.
/// Instruments are embedded (not registered globally) so each
/// server/test gets isolated counts; [`ServeStats::samples`] contributes
/// them to the scrape surface under the `invertnet_serve_*` names.
#[derive(Default)]
pub struct ServeStats {
    requests: Counter,
    batches: Counter,
    items: Counter,
    errors: Counter,
    /// Queue-to-reply latency, indexed by `Work::op_tag()` (0 = sample,
    /// 1 = score; the `posterior` op rides the sample path).
    lat_us: [Histogram; 2],
    batch_jobs: Histogram,
    batch_rows: Histogram,
    queue_depth: Gauge,
    models: Gauge,
    /// Per-phase request-lifecycle timings, indexed by [`phase`].
    phases: [Histogram; 6],
    /// Per-model request/row totals, exported as the labeled counter
    /// families `invertnet_serve_model_{requests,rows}_total`. Touched
    /// once per *batch* (not per request), so the lock is cold.
    per_model: Mutex<std::collections::BTreeMap<String, (u64, u64)>>,
}

impl ServeStats {
    fn record_batch(&self, jobs: usize, rows: usize) {
        self.requests.add(jobs as u64);
        self.batches.inc();
        self.items.add(rows as u64);
        self.batch_jobs.record(jobs as u64);
        self.batch_rows.record(rows as u64);
    }

    fn record_latency(&self, op: u8, us: u64) {
        self.lat_us[(op as usize).min(1)].record(us);
    }

    /// Record one request-lifecycle phase duration (see [`phase`]).
    pub fn record_phase(&self, p: usize, us: u64) {
        self.phases[p.min(phase::NAMES.len() - 1)].record(us);
    }

    fn record_model(&self, model: &str, jobs: u64, rows: u64) {
        if !crate::telemetry::enabled() {
            return;
        }
        let mut m = self.per_model.lock().unwrap();
        let e = m.entry(model.to_string()).or_insert((0, 0));
        e.0 += jobs;
        e.1 += rows;
    }

    pub fn record_error(&self) {
        self.errors.inc();
    }

    /// Snapshot with queue/registry gauges supplied by the caller.
    /// Percentiles come from the merge of both per-op histograms, so
    /// they describe every request ever answered, not a recent window.
    pub fn snapshot(&self, queue_depth: u64, models: u64) -> StatsSnapshot {
        self.queue_depth.set(queue_depth as f64);
        self.models.set(models as f64);
        let mut lat = self.lat_us[0].snapshot();
        lat.merge(&self.lat_us[1].snapshot());
        let requests = self.requests.get();
        let batches = self.batches.get();
        let items = self.items.get();
        StatsSnapshot {
            requests,
            batches,
            items,
            errors: self.errors.get(),
            mean_batch: if batches == 0 { 0.0 }
                        else { requests as f64 / batches as f64 },
            mean_items: if batches == 0 { 0.0 }
                        else { items as f64 / batches as f64 },
            p50_us: lat.quantile_u64(0.50),
            p99_us: lat.quantile_u64(0.99),
            p999_us: lat.quantile_u64(0.999),
            queue_depth,
            models,
        }
    }

    /// This instance's series for the metrics scrape, sorted by name.
    pub fn samples(&self) -> Vec<(String, Sample)> {
        let mut out = vec![
            ("invertnet_serve_batch_jobs".to_string(),
             Sample::Histogram(self.batch_jobs.snapshot())),
            ("invertnet_serve_batch_rows".to_string(),
             Sample::Histogram(self.batch_rows.snapshot())),
            ("invertnet_serve_batches_total".to_string(),
             Sample::Counter(self.batches.get())),
            ("invertnet_serve_errors_total".to_string(),
             Sample::Counter(self.errors.get())),
            ("invertnet_serve_items_total".to_string(),
             Sample::Counter(self.items.get())),
            ("invertnet_serve_models".to_string(),
             Sample::Gauge(self.models.get())),
            ("invertnet_serve_queue_depth".to_string(),
             Sample::Gauge(self.queue_depth.get())),
            ("invertnet_serve_requests_total".to_string(),
             Sample::Counter(self.requests.get())),
            ("invertnet_serve_sample_latency_us".to_string(),
             Sample::Histogram(self.lat_us[0].snapshot())),
            ("invertnet_serve_score_latency_us".to_string(),
             Sample::Histogram(self.lat_us[1].snapshot())),
        ];
        for (i, name) in phase::NAMES.iter().enumerate() {
            out.push((format!("invertnet_serve_phase_{name}_us"),
                      Sample::Histogram(self.phases[i].snapshot())));
        }
        // per-model breakdowns; a family with zero rows would render no
        // samples (which the parser rejects), so skip them before any
        // traffic has been served
        let per_model = self.per_model.lock().unwrap();
        if !per_model.is_empty() {
            let reqs: Vec<(String, u64)> =
                per_model.iter().map(|(m, (j, _))| (m.clone(), *j)).collect();
            let rows: Vec<(String, u64)> =
                per_model.iter().map(|(m, (_, r))| (m.clone(), *r)).collect();
            out.push(("invertnet_serve_model_requests_total".to_string(),
                      Sample::LabeledCounter { label: "model", values: reqs }));
            out.push(("invertnet_serve_model_rows_total".to_string(),
                      Sample::LabeledCounter { label: "model", values: rows }));
        }
        drop(per_model);
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }
}

// ---------------------------------------------------------------------------
// The batcher
// ---------------------------------------------------------------------------

struct Shared {
    cfg: BatchConfig,
    queue: Mutex<VecDeque<Job>>,
    /// Workers wait here for jobs / coalescing deadlines.
    work_cv: Condvar,
    /// Blocked submitters wait here for queue capacity.
    space_cv: Condvar,
    stop: AtomicBool,
    stats: Arc<ServeStats>,
}

/// Owns the worker pool; dropping it drains the queue and joins workers.
pub struct Batcher {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Batcher {
    pub fn new(cfg: BatchConfig, stats: Arc<ServeStats>) -> Batcher {
        let cfg = BatchConfig {
            max_batch: cfg.max_batch.max(1),
            workers: cfg.workers.max(1),
            queue_cap: cfg.queue_cap.max(1),
            ..cfg
        };
        let shared = Arc::new(Shared {
            cfg,
            queue: Mutex::new(VecDeque::new()),
            work_cv: Condvar::new(),
            space_cv: Condvar::new(),
            stop: AtomicBool::new(false),
            stats,
        });
        let workers = (0..shared.cfg.workers)
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("serve-worker-{i}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawn serve worker")
            })
            .collect();
        Batcher { shared, workers }
    }

    /// Queued (not yet executing) job count.
    pub fn queue_depth(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    /// The configured queue bound (readiness checks compare depth to it).
    pub fn queue_cap(&self) -> usize {
        self.shared.cfg.queue_cap
    }

    /// True while the full worker pool is running — a panicked or joined
    /// worker flips this, and `readyz` reports the daemon unready.
    pub fn workers_alive(&self) -> bool {
        !self.workers.is_empty() && self.workers.iter().all(|h| !h.is_finished())
    }

    /// Enqueue one job and return the receiver its reply will land on.
    /// Blocks while the queue is at capacity (bounded backpressure); gives
    /// up with an error after 30s so a wedged server can't strand clients.
    pub fn submit(&self, model: Arc<ServedModel>, work: Work)
                  -> Result<Receiver<Result<Reply>>> {
        self.submit_traced(model, work, String::new())
    }

    /// [`submit`](Self::submit) with the request's trace id attached to
    /// the job, so batch-side events can name the requests they served.
    pub fn submit_traced(&self, model: Arc<ServedModel>, work: Work,
                         trace_id: String)
                         -> Result<Receiver<Result<Reply>>> {
        if work.rows() == 0 {
            bail!("empty request (0 rows)");
        }
        let (tx, rx) = channel();
        let job = Job { model, work, tx, t_enq: Instant::now(), trace_id };
        let mut q = self.shared.queue.lock().unwrap();
        if q.len() >= self.shared.cfg.queue_cap {
            events::emit(Level::Warn, "queue_saturated", vec![
                ("depth", Json::Num(q.len() as f64)),
                ("cap", Json::Num(self.shared.cfg.queue_cap as f64)),
            ]);
        }
        while q.len() >= self.shared.cfg.queue_cap {
            if self.shared.stop.load(Ordering::Relaxed) {
                bail!("server is shutting down");
            }
            let (guard, timeout) = self.shared.space_cv
                .wait_timeout(q, Duration::from_secs(30))
                .unwrap();
            q = guard;
            if timeout.timed_out() && q.len() >= self.shared.cfg.queue_cap {
                bail!("server overloaded: queue has been full for 30s \
                       ({} jobs)", q.len());
            }
        }
        if self.shared.stop.load(Ordering::Relaxed) {
            bail!("server is shutting down");
        }
        q.push_back(job);
        drop(q);
        self.shared.work_cv.notify_all();
        Ok(rx)
    }

    /// Stop accepting work, drain what is queued, join the pool.
    pub fn shutdown(&mut self) {
        self.shared.stop.store(true, Ordering::Relaxed);
        self.shared.work_cv.notify_all();
        self.shared.space_cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(sh: &Shared) {
    loop {
        let batch = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if q.is_empty() {
                    if sh.stop.load(Ordering::Relaxed) {
                        return;
                    }
                    q = sh.work_cv.wait(q).unwrap();
                    continue;
                }
                // per-group (job count, oldest enqueue time); FIFO order
                // means the first job seen for a group is its oldest, and
                // the earliest deadline overall belongs to the queue head
                let mut groups: Vec<((usize, u8), usize, Instant)> =
                    Vec::new();
                for j in q.iter() {
                    let k = group_of(j);
                    match groups.iter_mut().find(|g| g.0 == k) {
                        Some(g) => g.1 += 1,
                        None => groups.push((k, 1, j.t_enq)),
                    }
                }
                // fire the first group that is ready: full, past its
                // oldest job's deadline, or draining for shutdown. Full
                // non-head groups fire immediately — they never wait out
                // the head's coalescing window.
                let now = Instant::now();
                let stop = sh.stop.load(Ordering::Relaxed);
                let ready = groups.iter().find(|(_, count, t0)| {
                    stop || *count >= sh.cfg.max_batch
                        || *t0 + sh.cfg.max_delay <= now
                });
                if let Some(&(key, _, _)) = ready {
                    break take_group(&mut q, key, sh.cfg.max_batch);
                }
                // wait out the earliest coalescing window (the head's) or
                // a new-job wakeup
                let deadline = q[0].t_enq + sh.cfg.max_delay;
                let (guard, _) = sh.work_cv
                    .wait_timeout(q, deadline - now)
                    .unwrap();
                q = guard;
            }
        };
        sh.space_cv.notify_all();
        execute_batch(batch, &sh.stats);
    }
}

/// Remove up to `cap` jobs of `key`'s group from the queue, preserving
/// FIFO order of everything (taken and left behind).
fn take_group(q: &mut VecDeque<Job>, key: (usize, u8), cap: usize)
              -> Vec<Job> {
    let mut taken = Vec::new();
    let mut rest = VecDeque::with_capacity(q.len());
    while let Some(j) = q.pop_front() {
        if taken.len() < cap && group_of(&j) == key {
            taken.push(j);
        } else {
            rest.push_back(j);
        }
    }
    std::mem::swap(q, &mut rest);
    taken
}

/// Run one coalesced pass and scatter row-slices back to each job.
fn execute_batch(jobs: Vec<Job>, stats: &ServeStats) {
    if jobs.is_empty() {
        return;
    }
    let t_taken = Instant::now();
    let rows: Vec<usize> = jobs.iter().map(|j| j.work.rows()).collect();
    let total: usize = rows.iter().sum();
    let op = jobs[0].work.op_tag();
    let n_jobs = jobs.len();
    let model_name = jobs[0].model.name.clone();
    let oldest = jobs
        .iter()
        .max_by_key(|j| t_taken.duration_since(j.t_enq))
        .expect("non-empty batch");
    let oldest_wait_us = t_taken.duration_since(oldest.t_enq).as_micros() as u64;
    let oldest_trace = oldest.trace_id.clone();
    let result = {
        let _sp = crate::span!("serve_batch");
        run_batch(&jobs, &rows)
    };
    stats.record_batch(n_jobs, total);
    stats.record_model(&model_name, n_jobs as u64, total as u64);
    match result {
        Ok((payloads, assembly_us, execute_us)) => {
            stats.record_phase(phase::BATCH_ASSEMBLY, assembly_us);
            stats.record_phase(phase::EXECUTE, execute_us);
            events::emit(Level::Info, "batch_fired", vec![
                ("model", Json::Str(model_name)),
                ("jobs", Json::Num(n_jobs as f64)),
                ("rows", Json::Num(total as f64)),
                ("oldest_wait_us", Json::Num(oldest_wait_us as f64)),
                ("oldest_trace_id", Json::Str(oldest_trace)),
            ]);
            for (job, payload) in jobs.into_iter().zip(payloads) {
                let queue_wait_us =
                    t_taken.duration_since(job.t_enq).as_micros() as u64;
                stats.record_phase(phase::QUEUE_WAIT, queue_wait_us);
                let us = job.t_enq.elapsed().as_micros() as u64;
                stats.record_latency(op, us);
                let times = BatchTimes {
                    queue_wait_us,
                    assembly_us,
                    execute_us,
                    batch_jobs: n_jobs as u64,
                    batch_rows: total as u64,
                };
                // receiver may have left
                let _ = job.tx.send(Ok(Reply { payload, times }));
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            events::emit(Level::Error, "batch_error", vec![
                ("model", Json::Str(model_name)),
                ("jobs", Json::Num(n_jobs as f64)),
                ("error", Json::Str(msg.clone())),
            ]);
            for job in jobs {
                stats.record_error();
                let _ = job.tx.send(Err(anyhow!("{msg}")));
            }
        }
    }
}

/// The batched pass itself: concatenate the group's payloads along axis 0,
/// run ONE inverse/forward pass on a forked flow (fresh ledger per pass),
/// slice the result back per job. Row-major concat + batch-elementwise
/// layer programs make each slice bit-identical to a private pass. The
/// fork inherits the engine's inference thread count, so a pass larger
/// than the network's canonical batch additionally chunks across the
/// intra-pass worker pool (see the module docs), still bit-identically.
fn run_batch(jobs: &[Job], rows: &[usize])
             -> Result<(Vec<ReplyPayload>, u64, u64)> {
    let model = &jobs[0].model;
    let flow = model.flow.fork();
    match &jobs[0].work {
        Work::Sample { .. } => {
            let t_asm = Instant::now();
            let n_sites = flow.def.latent_shapes.len();
            let mut cat_sites = Vec::with_capacity(n_sites);
            for site in 0..n_sites {
                let parts: Vec<&Tensor> = jobs.iter().map(|j| match &j.work {
                    Work::Sample { latents, .. } => &latents[site],
                    Work::Score { .. } => unreachable!("mixed batch group"),
                }).collect();
                cat_sites.push(concat_rows(&parts)?);
            }
            let cond = batch_cond(jobs)?;
            let assembly_us = t_asm.elapsed().as_micros() as u64;
            let t_exec = Instant::now();
            let x = flow.invert(&cat_sites, &model.params,
                                InferOpts::relaxed().cond_opt(cond.as_ref()))?;
            let mut out = Vec::with_capacity(jobs.len());
            let mut off = 0;
            for &n in rows {
                out.push(ReplyPayload::Samples(slice_rows(&x, off, n)?));
                off += n;
            }
            Ok((out, assembly_us, t_exec.elapsed().as_micros() as u64))
        }
        Work::Score { .. } => {
            let t_asm = Instant::now();
            let parts: Vec<&Tensor> = jobs.iter().map(|j| match &j.work {
                Work::Score { x, .. } => x,
                Work::Sample { .. } => unreachable!("mixed batch group"),
            }).collect();
            let x = concat_rows(&parts)?;
            let cond = batch_cond(jobs)?;
            let assembly_us = t_asm.elapsed().as_micros() as u64;
            let t_exec = Instant::now();
            let scores = flow.log_density(
                &x, &model.params, InferOpts::relaxed().cond_opt(cond.as_ref()))?;
            let mut out = Vec::with_capacity(jobs.len());
            let mut off = 0;
            for &n in rows {
                out.push(ReplyPayload::Scores(scores[off..off + n].to_vec()));
                off += n;
            }
            Ok((out, assembly_us, t_exec.elapsed().as_micros() as u64))
        }
    }
}

/// Concatenate the jobs' conditioning rows (all or none must carry one;
/// the flow validates the merged shape).
fn batch_cond(jobs: &[Job]) -> Result<Option<Tensor>> {
    let conds: Vec<&Tensor> = jobs.iter().filter_map(|j| match &j.work {
        Work::Sample { cond, .. } | Work::Score { cond, .. } => cond.as_ref(),
    }).collect();
    if conds.is_empty() {
        return Ok(None);
    }
    if conds.len() != jobs.len() {
        bail!("batch mixes conditioned and unconditioned requests \
               for one model");
    }
    Ok(Some(concat_rows(&conds)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Engine;
    use crate::serve::registry::Registry;
    use crate::util::rng::Pcg64;

    fn model() -> (Registry, Arc<ServedModel>) {
        let r = Registry::new(Engine::native().unwrap(), 4);
        let m = r.register_untrained("realnvp2d", 11).unwrap();
        (r, m)
    }

    fn score_work(m: &ServedModel, seed: u64, n: usize) -> Work {
        let mut rng = Pcg64::new(seed);
        let d = m.flow.def.in_shape[1];
        Work::Score {
            x: Tensor { shape: vec![n, d], data: rng.normal_vec(n * d) },
            cond: None,
        }
    }

    #[test]
    fn scores_match_direct_calls_bit_exactly() {
        let (_r, m) = model();
        let stats = Arc::new(ServeStats::default());
        let b = Batcher::new(BatchConfig {
            max_batch: 4,
            max_delay: Duration::from_millis(20),
            workers: 2,
            queue_cap: 64,
        }, stats.clone());

        // burst several jobs inside one coalescing window
        let rxs: Vec<_> = (0..6).map(|i| {
            b.submit(m.clone(), score_work(&m, 100 + i, 1 + (i % 3) as usize))
                .unwrap()
        }).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let i = i as u64;
            let reply = rx.recv().unwrap().unwrap();
            assert!(reply.times.batch_jobs >= 1, "{:?}", reply.times);
            assert!(reply.times.batch_rows as usize >= 1, "{:?}", reply.times);
            let ReplyPayload::Scores(got) = reply.payload else {
                panic!("wrong reply kind")
            };
            let Work::Score { x, .. } = score_work(&m, 100 + i,
                                                   1 + (i % 3) as usize)
            else { unreachable!() };
            let want = m.flow.log_density(&x, &m.params,
                                          InferOpts::relaxed()).unwrap();
            assert_eq!(got.len(), want.len());
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(),
                           "job {i}: batched {a} != direct {b}");
            }
        }
        let snap = stats.snapshot(0, 1);
        assert_eq!(snap.requests, 6);
        assert!(snap.batches <= 6);

        // batch-side phase histograms and per-model counters rode along
        let samples = stats.samples();
        let (_, qw) = samples
            .iter()
            .find(|(n, _)| n == "invertnet_serve_phase_queue_wait_us")
            .expect("phase histogram exported");
        match qw {
            Sample::Histogram(h) => assert_eq!(h.count, 6, "one per job"),
            other => panic!("expected histogram, got {other:?}"),
        }
        let (_, pm) = samples
            .iter()
            .find(|(n, _)| n == "invertnet_serve_model_requests_total")
            .expect("per-model counter exported");
        match pm {
            Sample::LabeledCounter { label, values } => {
                assert_eq!(*label, "model");
                assert_eq!(values, &[("realnvp2d".to_string(), 6)]);
            }
            other => panic!("expected labeled counter, got {other:?}"),
        }
    }

    #[test]
    fn coalescing_actually_batches_under_burst() {
        let (_r, m) = model();
        let stats = Arc::new(ServeStats::default());
        let b = Batcher::new(BatchConfig {
            max_batch: 8,
            max_delay: Duration::from_millis(50),
            workers: 1,
            queue_cap: 64,
        }, stats.clone());
        let rxs: Vec<_> = (0..8).map(|i| {
            b.submit(m.clone(), score_work(&m, i, 1)).unwrap()
        }).collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let snap = stats.snapshot(0, 1);
        assert_eq!(snap.requests, 8);
        // one worker + 50ms window + burst of 8 = very few passes
        assert!(snap.batches <= 3, "expected coalescing, got {snap:?}");
        assert!(snap.mean_batch >= 2.0, "{snap:?}");
    }

    #[test]
    fn execution_errors_reach_every_job() {
        let (_r, m) = model();
        let stats = Arc::new(ServeStats::default());
        let b = Batcher::new(BatchConfig::default(), stats.clone());
        // wrong per-sample width -> the batched pass fails
        let bad = Work::Score {
            x: Tensor::zeros(&[2, 5]),
            cond: None,
        };
        let rx = b.submit(m.clone(), bad).unwrap();
        assert!(rx.recv().unwrap().is_err());
        assert_eq!(stats.snapshot(0, 1).errors, 1);
    }

    #[test]
    fn rejects_empty_work() {
        let (_r, m) = model();
        let b = Batcher::new(BatchConfig::default(),
                             Arc::new(ServeStats::default()));
        let empty = Work::Score { x: Tensor::zeros(&[0, 2]), cond: None };
        assert!(b.submit(m, empty).is_err());
    }
}
