//! The model registry: loads and caches `(Flow, ParamStore)` pairs from
//! checkpoint directories, LRU-capped so a long-lived server can front many
//! checkpoints without holding them all resident.
//!
//! A checkpoint directory is what [`crate::flow::ParamStore::save`] writes
//! (`index.json` + one `.npy` per parameter); its `"network"` field names
//! the catalog entry, so `--net` never needs repeating at serve time.
//! Models can be warmed eagerly at startup ([`Registry::register_checkpoint`])
//! or resolved lazily on first request from a root directory
//! ([`Registry::with_root`]).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::api::Engine;
use crate::flow::ParamStore;
use crate::telemetry::events::{self, Level};
use crate::telemetry::{Counter, Sample};
use crate::util::json::Json;
use crate::Flow;

/// One servable model: an owned flow handle plus its (shared, immutable)
/// weights. Workers `fork()` the flow per batch so each batched pass is
/// metered on its own ledger.
pub struct ServedModel {
    pub name: String,
    pub flow: Flow,
    pub params: Arc<ParamStore>,
    /// False when the weights are a random init (no checkpoint) — the
    /// server refuses such models unless explicitly allowed, so a typo'd
    /// path can't silently serve noise.
    pub trained: bool,
}

struct Inner {
    /// Resident models, keyed by registered name.
    map: BTreeMap<String, Arc<ServedModel>>,
    /// LRU order: most recently used at the back.
    lru: Vec<String>,
    /// Target of requests with no `"model"`: the first-registered model,
    /// reassigned to the most recently used survivor if evicted.
    default_name: Option<String>,
}

/// LRU-capped model cache over an [`Engine`].
pub struct Registry {
    engine: Engine,
    cap: usize,
    root: Option<PathBuf>,
    inner: Mutex<Inner>,
    /// Models admitted (registered or lazily loaded), LRU victims, and
    /// checkpoints refused by admission control (budget/static checks).
    /// Embedded so each registry/test gets isolated counts; exported at
    /// scrape time via [`Registry::samples`].
    loads: Counter,
    evictions: Counter,
    rejects: Counter,
}

impl Registry {
    /// A registry holding at most `cap` resident models (`cap >= 1`).
    pub fn new(engine: Engine, cap: usize) -> Registry {
        Registry {
            engine,
            cap: cap.max(1),
            root: None,
            inner: Mutex::new(Inner {
                map: BTreeMap::new(),
                lru: Vec::new(),
                default_name: None,
            }),
            loads: Counter::new(),
            evictions: Counter::new(),
            rejects: Counter::new(),
        }
    }

    /// Like [`Registry::new`], additionally resolving cache misses from
    /// `root`: a request for model `m` tries `root/m` then
    /// `root/m/checkpoint` as checkpoint directories.
    pub fn with_root(engine: Engine, cap: usize, root: impl Into<PathBuf>)
                     -> Registry {
        let mut r = Registry::new(engine, cap);
        r.root = Some(root.into());
        r
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Resident model count.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The network name recorded in a checkpoint's `index.json`.
    pub fn checkpoint_network_name(dir: &Path) -> Result<String> {
        let text = std::fs::read_to_string(dir.join("index.json"))
            .with_context(|| format!("reading checkpoint {dir:?}"))?;
        Ok(Json::parse(&text)?.req("network")?.as_str()?.to_string())
    }

    /// Load a checkpoint directory into a ready `(Flow, ParamStore)` pair
    /// — the one checkpoint-loading sequence, shared by the registry and
    /// the offline CLI paths (`invertnet score`).
    pub fn load_checkpoint(engine: &Engine, dir: &Path)
                           -> Result<(Flow, ParamStore)> {
        let net = Self::checkpoint_network_name(dir)?;
        let flow = engine.flow(&net)?;
        // static admission control: with an engine-wide memory budget,
        // even the most frugal schedule's predicted peak must fit — a
        // model that can't is rejected here, before any weight bytes load
        // or allocations happen
        if let Some(budget) = engine.mem_budget() {
            let peak = crate::analysis::predict_peak(
                &flow.def, &crate::coordinator::ExecMode::Invertible);
            if peak > budget {
                bail!("checkpoint {dir:?} network {net:?} cannot fit the \
                       {budget}-byte memory budget: its minimum predicted \
                       peak (invertible schedule) is {peak} bytes");
            }
        }
        // static shape check BEFORE any weight bytes load: the name alone
        // proves nothing, and ParamStore::load silently keeps the random
        // init for params the index omits — a mismatched or truncated
        // checkpoint must be rejected here, not served
        let issues = crate::analysis::verify_checkpoint_index(
            engine.manifest(), &flow.def, dir)?;
        let errors: Vec<String> = issues.iter()
            .filter(|d| d.is_error())
            .map(|d| d.to_string())
            .collect();
        if !errors.is_empty() {
            bail!("checkpoint {dir:?} fails static validation against \
                   network {net:?}:\n  {}", errors.join("\n  "));
        }
        // the checkpoint holds every parameter (verified above), so the
        // init seed below is fully overwritten; load() validates names
        // and shapes again as it reads
        let mut params = flow.init_params(0)?;
        params.load(dir)
            .with_context(|| format!("loading checkpoint {dir:?}"))?;
        // apply the engine's weight-storage dtype (--weight-dtype bf16/f16)
        // once, at load: compute stays f32 over the rounded values
        engine.load_weights(&mut params);
        Ok((flow, params))
    }

    /// Load a checkpoint directory and register it under its network name.
    /// A load refused by admission control (memory budget, static
    /// checkpoint validation) counts toward the rejects series.
    pub fn register_checkpoint(&self, dir: &Path) -> Result<Arc<ServedModel>> {
        let (flow, params) = match Self::load_checkpoint(&self.engine, dir) {
            Ok(pair) => pair,
            Err(e) => {
                self.rejects.inc();
                events::emit(Level::Error, "model_reject", vec![
                    ("dir", Json::Str(format!("{dir:?}"))),
                    ("error", Json::Str(format!("{e:#}"))),
                ]);
                return Err(e);
            }
        };
        self.insert(ServedModel {
            name: flow.def.name.clone(),
            flow,
            params: Arc::new(params),
            trained: true,
        })
    }

    /// Register a random init of catalog network `net` (tests, and the
    /// explicitly-allowed untrained serving path).
    pub fn register_untrained(&self, net: &str, seed: u64)
                              -> Result<Arc<ServedModel>> {
        let flow = self.engine.flow(net)?;
        let params = Arc::new(flow.init_params(seed)?);
        self.insert(ServedModel {
            name: net.to_string(),
            flow,
            params,
            trained: false,
        })
    }

    /// Register a fully-formed model (callers that already hold trained
    /// weights in memory, e.g. a train-then-serve pipeline or tests).
    pub fn insert(&self, model: ServedModel) -> Result<Arc<ServedModel>> {
        let model = Arc::new(model);
        let mut inner = self.inner.lock().unwrap();
        let name = model.name.clone();
        inner.map.insert(name.clone(), model.clone());
        inner.lru.retain(|n| n != &name);
        inner.lru.push(name.clone());
        if inner.default_name.is_none() {
            inner.default_name = Some(name);
        }
        // LRU eviction (never evicts what was just inserted: it is at the
        // back of the order). If the default model is evicted, the default
        // passes to the most recently used survivor so requests that omit
        // `"model"` keep resolving.
        while inner.map.len() > self.cap {
            let victim = inner.lru.remove(0);
            inner.map.remove(&victim);
            self.evictions.inc();
            events::emit(Level::Info, "model_evict", vec![
                ("model", Json::Str(victim.clone())),
            ]);
            if inner.default_name.as_deref() == Some(victim.as_str()) {
                inner.default_name = inner.lru.last().cloned();
            }
        }
        self.loads.inc();
        events::emit(Level::Info, "model_load", vec![
            ("model", Json::Str(model.name.clone())),
            ("trained", Json::Bool(model.trained)),
        ]);
        Ok(model)
    }

    /// This registry's series for the metrics scrape, sorted by name.
    pub fn samples(&self) -> Vec<(String, Sample)> {
        vec![
            ("invertnet_registry_evictions_total".to_string(),
             Sample::Counter(self.evictions.get())),
            ("invertnet_registry_loads_total".to_string(),
             Sample::Counter(self.loads.get())),
            ("invertnet_registry_rejects_total".to_string(),
             Sample::Counter(self.rejects.get())),
        ]
    }

    /// Look up a model by name (`None` = the default model), touching the
    /// LRU order. Misses fall back to the lazy root, if configured.
    pub fn get(&self, name: Option<&str>) -> Result<Arc<ServedModel>> {
        let wanted: String = {
            let inner = self.inner.lock().unwrap();
            match name {
                Some(n) => n.to_string(),
                None => match &inner.default_name {
                    Some(d) => d.clone(),
                    None => bail!("registry has no models"),
                },
            }
        };
        if let Some(m) = self.touch(&wanted) {
            return Ok(m);
        }
        // lazy load from the root directory
        let Some(root) = &self.root else {
            bail!("model {wanted:?} is not registered");
        };
        for dir in [root.join(&wanted), root.join(&wanted).join("checkpoint")] {
            if dir.join("index.json").is_file() {
                // verify the name BEFORE registering — a mismatched
                // checkpoint must not pollute the registry (or become the
                // default model) on its way to an error
                let actual = Self::checkpoint_network_name(&dir)?;
                if actual != wanted {
                    bail!("checkpoint {dir:?} holds network {actual:?}, \
                           not {wanted:?}");
                }
                return self.register_checkpoint(&dir);
            }
        }
        bail!("model {wanted:?} not registered and no checkpoint under \
               {root:?}")
    }

    /// Resident names in LRU order (oldest first) — for `stats`/debugging.
    pub fn resident(&self) -> Vec<String> {
        self.inner.lock().unwrap().lru.clone()
    }

    fn touch(&self, name: &str) -> Option<Arc<ServedModel>> {
        let mut inner = self.inner.lock().unwrap();
        let m = inner.map.get(name).cloned()?;
        inner.lru.retain(|n| n != name);
        inner.lru.push(name.to_string());
        Some(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry(cap: usize) -> Registry {
        Registry::new(Engine::native().unwrap(), cap)
    }

    #[test]
    fn default_model_is_first_registered() {
        let r = registry(4);
        assert!(r.get(None).is_err());
        r.register_untrained("realnvp2d", 1).unwrap();
        r.register_untrained("hint8d", 1).unwrap();
        assert_eq!(r.get(None).unwrap().name, "realnvp2d");
        assert_eq!(r.get(Some("hint8d")).unwrap().name, "hint8d");
        assert!(r.get(Some("nope")).is_err());
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let r = registry(2);
        r.register_untrained("realnvp2d", 1).unwrap();
        r.register_untrained("hint8d", 1).unwrap();
        // touch realnvp2d so hint8d is the LRU victim
        r.get(Some("realnvp2d")).unwrap();
        r.register_untrained("nice16", 1).unwrap();
        assert_eq!(r.len(), 2);
        assert_eq!(r.resident(), vec!["realnvp2d", "nice16"]);
        assert!(r.get(Some("hint8d")).is_err()); // evicted, no lazy root
    }

    #[test]
    fn evicting_the_default_model_reassigns_it() {
        let r = registry(2);
        r.register_untrained("realnvp2d", 1).unwrap(); // default
        r.register_untrained("hint8d", 1).unwrap();
        r.register_untrained("nice16", 1).unwrap(); // evicts realnvp2d
        // requests without "model" must keep resolving
        assert_eq!(r.get(None).unwrap().name, "nice16");
    }

    #[test]
    fn mismatched_lazy_checkpoint_does_not_pollute_the_registry() {
        let root = std::env::temp_dir()
            .join(format!("reg_badroot_{}", std::process::id()));
        let engine = Engine::native().unwrap();
        let flow = engine.flow("realnvp2d").unwrap();
        let params = flow.init_params(5).unwrap();
        // dir named "foo" but the checkpoint inside names realnvp2d
        params.save(&root.join("foo"), "realnvp2d").unwrap();

        let r = Registry::with_root(Engine::native().unwrap(), 2, &root);
        let err = r.get(Some("foo")).unwrap_err();
        assert!(format!("{err:#}").contains("realnvp2d"), "{err:#}");
        // nothing was registered on the way to the error
        assert!(r.is_empty());
        assert!(r.get(None).is_err());
        std::fs::remove_dir_all(&root).ok();
    }

    /// Regression: lazy-root loads used to verify only the network
    /// *name* in `index.json`. A checkpoint that names `realnvp2d` but
    /// records wrong-shaped params must fail the static shape check
    /// before any weight loads — and before anything reaches the LRU.
    #[test]
    fn lazy_checkpoint_with_mismatched_shapes_is_rejected() {
        let root = std::env::temp_dir()
            .join(format!("reg_badshape_{}", std::process::id()));
        let engine = Engine::native().unwrap();
        // nice16-shaped params saved under the name realnvp2d: the name
        // check passes, the shapes cannot
        let flow = engine.flow("nice16").unwrap();
        let params = flow.init_params(5).unwrap();
        params.save(&root.join("realnvp2d"), "realnvp2d").unwrap();

        let r = Registry::with_root(Engine::native().unwrap(), 2, &root);
        let err = r.get(Some("realnvp2d")).unwrap_err();
        assert!(format!("{err:#}").contains("static validation"), "{err:#}");
        assert!(r.is_empty());
        std::fs::remove_dir_all(&root).ok();
    }

    /// Regression: an index.json that omits params would load "cleanly"
    /// (`ParamStore::load` skips what the index never mentions), leaving
    /// those params at the random init. The static check refuses it.
    #[test]
    fn truncated_checkpoint_is_rejected_statically() {
        let dir = std::env::temp_dir()
            .join(format!("reg_trunc_{}", std::process::id()));
        let engine = Engine::native().unwrap();
        let flow = engine.flow("realnvp2d").unwrap();
        let params = flow.init_params(3).unwrap();
        params.save(&dir, "realnvp2d").unwrap();
        let text = std::fs::read_to_string(dir.join("index.json")).unwrap();
        let mut doc = Json::parse(&text).unwrap();
        {
            let Json::Obj(m) = &mut doc else { panic!("index not an obj") };
            let Some(Json::Arr(entries)) = m.get_mut("params") else {
                panic!("no params array")
            };
            entries.truncate(entries.len() / 2);
        }
        std::fs::write(dir.join("index.json"), doc.to_string()).unwrap();

        let err = Registry::load_checkpoint(&engine, &dir).unwrap_err();
        assert!(format!("{err:#}").contains("ckpt-missing-param"),
                "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The memory budget is *static admission control*: a model whose
    /// minimum predicted peak (invertible schedule) can't fit the
    /// engine's budget is rejected at load, before any weights are read.
    #[test]
    fn budgeted_engine_rejects_oversized_models_at_load() {
        use crate::backend::RefBackend;

        let dir = std::env::temp_dir()
            .join(format!("reg_budget_{}", std::process::id()));
        let engine = Engine::native().unwrap();
        let flow = engine.flow("realnvp2d").unwrap();
        flow.init_params(9).unwrap().save(&dir, "realnvp2d").unwrap();
        let min_peak = crate::analysis::predict_peak(
            &flow.def, &crate::coordinator::ExecMode::Invertible);

        let budgeted = |b: i64| Engine::builder()
            .backend(Arc::new(RefBackend::new()))
            .mem_budget(b)
            .build()
            .unwrap();
        let err = Registry::load_checkpoint(&budgeted(min_peak - 1), &dir)
            .unwrap_err();
        assert!(format!("{err:#}").contains("memory budget"), "{err:#}");
        // at exactly the minimum peak the model is admitted
        Registry::load_checkpoint(&budgeted(min_peak), &dir).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn telemetry_counters_track_loads_evictions_rejects() {
        let counts = |r: &Registry| -> Vec<u64> {
            r.samples().iter().map(|(_, s)| match s {
                Sample::Counter(v) => *v,
                other => panic!("registry exports counters only: {other:?}"),
            }).collect()
        };
        let r = registry(2);
        assert_eq!(counts(&r), vec![0, 0, 0]);
        r.register_untrained("realnvp2d", 1).unwrap();
        r.register_untrained("hint8d", 1).unwrap();
        r.register_untrained("nice16", 1).unwrap(); // evicts realnvp2d
        // samples() is sorted by name: evictions, loads, rejects
        assert_eq!(counts(&r), vec![1, 3, 0]);

        // a bad checkpoint dir is an admission reject, not a load
        let dir = std::env::temp_dir()
            .join(format!("reg_telem_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("index.json"), "{").unwrap();
        assert!(r.register_checkpoint(&dir).is_err());
        assert_eq!(counts(&r), vec![1, 3, 1]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checkpoint_roundtrip_through_registry() {
        let dir = std::env::temp_dir()
            .join(format!("reg_ckpt_{}", std::process::id()));
        let engine = Engine::native().unwrap();
        let flow = engine.flow("realnvp2d").unwrap();
        let params = flow.init_params(123).unwrap();
        params.save(&dir, "realnvp2d").unwrap();

        let r = registry(2);
        let m = r.register_checkpoint(&dir).unwrap();
        assert_eq!(m.name, "realnvp2d");
        assert!(m.trained);
        for (a, b) in m.params.tensors.iter().flatten()
            .zip(params.tensors.iter().flatten()) {
            assert_eq!(a, b, "registry-loaded params differ");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lazy_root_loads_on_miss() {
        let root = std::env::temp_dir()
            .join(format!("reg_root_{}", std::process::id()));
        let engine = Engine::native().unwrap();
        let flow = engine.flow("hint8d").unwrap();
        let params = flow.init_params(5).unwrap();
        // train-loop layout: <root>/<name>/checkpoint
        params.save(&root.join("hint8d").join("checkpoint"), "hint8d").unwrap();

        let r = Registry::with_root(Engine::native().unwrap(), 2, &root);
        let m = r.get(Some("hint8d")).unwrap();
        assert_eq!(m.name, "hint8d");
        assert!(m.trained);
        std::fs::remove_dir_all(&root).ok();
    }
}
