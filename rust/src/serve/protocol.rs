//! The JSON-lines request/response protocol — one JSON object per line,
//! transport-agnostic (the same frames flow over the loopback TCP listener
//! and the stdio loop).
//!
//! Requests (defaults in parens):
//!
//! ```text
//! {"op":"sample","n":4,"seed":1,"temperature":0.8,"model":"realnvp2d",
//!  "cond":{"shape":[4,2],"data":[...]}}        n(1) seed(0) temperature(1)
//! {"op":"score","x":{"shape":[2,2],"data":[0.1,0.2,0.3,0.4]}}
//! {"op":"posterior","y":[0.7,-0.4],"n":64,"seed":1,"samples":true}
//!                                              n(64) seed(0) temperature(1)
//! {"op":"stats"}
//! {"op":"metrics"}
//! {"op":"debug-dump"}
//! {"op":"shutdown"}
//! ```
//!
//! Any request may additionally carry two meta fields ([`ReqMeta`]):
//! `"trace_id":"..."` names the request in phase histograms and events
//! (assigned by the server when absent), and `"timing":true` asks the
//! server to echo the per-phase [`Timing`] block. Both are additive —
//! they add response keys but never change payload fields, so
//! micro-batching stays bit-invisible with tracing on.
//!
//! Responses always carry `"ok"`:
//!
//! ```text
//! {"ok":true,"op":"sample","x":{"shape":[4,2],"data":[...]}}
//! {"ok":true,"op":"score","log_density":[-2.71,-3.14]}
//! {"ok":true,"op":"posterior","n":64,"mean":[...],"std":[...],
//!  "x":{"shape":[64,2],"data":[...]}}          x only with "samples":true
//! {"ok":true,"op":"stats","stats":{...}}
//! {"ok":true,"op":"metrics","text":"# TYPE ...\n..."}
//! {"ok":true,"op":"debug-dump","report":{...}}  invertnet-dump/v1 report
//! {"ok":true,"op":"shutdown"}
//! {"ok":false,"error":"..."}
//! ```
//!
//! `posterior` targets a *conditional* model: `y` is one observation row
//! (a plain f32 array); the server tiles it across `n` conditioning rows,
//! draws latents from `Pcg64::new(seed)`, runs the batched inverse, and
//! summarizes — bit-identical to the in-process
//! `posterior::analysis::posterior_samples` + `summarize` path.
//!
//! `model` is optional everywhere a model is needed; omitting it targets
//! the registry's default (first-registered) model. Tensor payloads are
//! `{"shape":[...],"data":[flat row-major f32...]}`. f32 values survive
//! the wire bit-exactly: they are widened to f64, printed with Rust's
//! shortest-roundtrip formatter, and narrowed back on parse — the
//! micro-batched server is bit-identical to direct in-process calls.
//! Seeds at or above 2^53 are sent as strings (`"seed":"18446..."`),
//! since a JSON number that large may not represent them exactly.

use anyhow::{anyhow, bail, Result};

use crate::tensor::Tensor;
use crate::util::json::Json;

/// Upper bound on samples per request (keeps one request from forcing a
/// giant allocation; batch across requests instead).
pub const MAX_SAMPLES_PER_REQUEST: usize = 65_536;

/// A parsed client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Draw `n` samples at latent `temperature`, latents seeded from
    /// `seed` — bit-identical to
    /// `Flow::sample(&params, SampleOpts::new(n, &mut Pcg64::new(seed))
    ///                  .temperature(temperature).cond_opt(cond))`.
    Sample {
        model: Option<String>,
        n: usize,
        temperature: f32,
        seed: u64,
        cond: Option<Tensor>,
    },
    /// Per-sample log-density scores for a batch `x` (leading dim = batch).
    Score {
        model: Option<String>,
        x: Tensor,
        cond: Option<Tensor>,
    },
    /// Amortized posterior query: `n` draws x ~ p(x | y) for one
    /// observation row `y`, plus pointwise mean/std maps. The full sample
    /// cloud is returned only when `return_samples` is set.
    Posterior {
        model: Option<String>,
        y: Vec<f32>,
        n: usize,
        temperature: f32,
        seed: u64,
        return_samples: bool,
    },
    /// Serving metrics snapshot.
    Stats,
    /// Full telemetry scrape as Prometheus text exposition.
    Metrics,
    /// Flight-recorder dump: the last N structured events as an
    /// `invertnet-dump/v1` incident report.
    DebugDump,
    /// Stop the server after responding.
    Shutdown,
}

/// Request metadata that rides alongside any op: an optional
/// client-supplied `"trace_id"` (the server assigns one when absent) and
/// the `"timing":true` flag asking for the per-phase [`Timing`] block in
/// the response. Parsed from the same JSON object as the [`Request`] but
/// kept separate so the op payloads (and their bit-exactness contracts)
/// are untouched by tracing.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ReqMeta {
    pub trace_id: Option<String>,
    pub timing: bool,
}

impl ReqMeta {
    pub fn from_json(j: &Json) -> Result<ReqMeta> {
        let trace_id = match j.get("trace_id") {
            None => None,
            Some(v) => {
                let s = v.as_str()?;
                if s.is_empty() || s.len() > 128 {
                    bail!("trace_id must be 1..=128 characters, \
                           got {} bytes", s.len());
                }
                if s.chars().any(|c| c.is_control()) {
                    bail!("trace_id must not contain control characters");
                }
                Some(s.to_string())
            }
        };
        let timing = match j.get("timing") {
            None => false,
            Some(Json::Bool(b)) => *b,
            Some(other) => bail!("timing flag must be a bool, got {other:?}"),
        };
        Ok(ReqMeta { trace_id, timing })
    }
}

/// Per-phase request timing echoed when the request set `"timing":true`.
/// All microseconds. `queue_wait`/`batch_assembly`/`execute` come from
/// the batch side ([`super::batcher::BatchTimes`]) and are zero for ops
/// that never queue (`stats`, `metrics`, ...). There is deliberately no
/// `encode_us` field: the block is serialized *inside* the encode phase,
/// so that phase is observable only through its histogram
/// (`invertnet_serve_phase_encode_us`).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Timing {
    pub parse_us: u64,
    pub validate_us: u64,
    pub queue_wait_us: u64,
    pub batch_assembly_us: u64,
    pub execute_us: u64,
    pub total_us: u64,
    pub batch_jobs: u64,
    pub batch_rows: u64,
}

impl Timing {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("parse_us", Json::Num(self.parse_us as f64)),
            ("validate_us", Json::Num(self.validate_us as f64)),
            ("queue_wait_us", Json::Num(self.queue_wait_us as f64)),
            ("batch_assembly_us", Json::Num(self.batch_assembly_us as f64)),
            ("execute_us", Json::Num(self.execute_us as f64)),
            ("total_us", Json::Num(self.total_us as f64)),
            ("batch_jobs", Json::Num(self.batch_jobs as f64)),
            ("batch_rows", Json::Num(self.batch_rows as f64)),
        ])
    }
}

/// Attach response extras (`trace_id`, `timing`) to an encoded response
/// object. Kept outside `Response::to_json` so the response enum — and
/// the payload bytes every bit-identity test pins — never varies with
/// tracing: extras only *add* keys.
pub fn decorate(mut j: Json, trace_id: Option<&str>, timing: Option<&Timing>)
                -> Json {
    if let Json::Obj(m) = &mut j {
        if let Some(t) = trace_id {
            m.insert("trace_id".to_string(), Json::Str(t.to_string()));
        }
        if let Some(t) = timing {
            m.insert("timing".to_string(), t.to_json());
        }
    }
    j
}

/// A server response, ready to serialize as one JSON line.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Sample { x: Tensor },
    Score { log_density: Vec<f32> },
    /// Posterior summary (and optionally the sample cloud) for one
    /// observation.
    Posterior {
        n: usize,
        mean: Vec<f32>,
        std: Vec<f32>,
        samples: Option<Tensor>,
    },
    Stats(StatsSnapshot),
    /// Prometheus text exposition of every series the server exports.
    Metrics { text: String },
    /// Flight-recorder dump (`invertnet-dump/v1`), already assembled.
    DebugDump { report: Json },
    Shutdown,
    Error { error: String },
}

/// Point-in-time serving metrics (see `batcher::ServeStats`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StatsSnapshot {
    /// Requests answered through the batcher (sample + score).
    pub requests: u64,
    /// Batched passes executed.
    pub batches: u64,
    /// Total samples/rows across those passes.
    pub items: u64,
    /// Requests that ended in an error reply.
    pub errors: u64,
    /// Mean requests coalesced per pass (`requests / batches`).
    pub mean_batch: f64,
    /// Mean rows per pass (`items / batches`).
    pub mean_items: f64,
    /// Median request latency (enqueue -> reply), microseconds.
    pub p50_us: u64,
    /// 99th-percentile request latency, microseconds.
    pub p99_us: u64,
    /// 99.9th-percentile request latency, microseconds.
    pub p999_us: u64,
    /// Jobs waiting in the queue at snapshot time.
    pub queue_depth: u64,
    /// Models resident in the registry.
    pub models: u64,
}

// ---------------------------------------------------------------------------
// Tensor / f32-array payload helpers
// ---------------------------------------------------------------------------

/// `{"shape":[...],"data":[...]}` — non-finite values cross as `null`.
pub fn tensor_to_json(t: &Tensor) -> Json {
    Json::obj(vec![
        ("shape", Json::arr_usize(&t.shape)),
        ("data", f32s_to_json(&t.data)),
    ])
}

pub fn tensor_from_json(j: &Json) -> Result<Tensor> {
    let shape = j.req("shape")?.as_usize_vec()?;
    let data = f32s_from_json(j.req("data")?)?;
    Tensor::new(shape, data)
}

fn f32s_to_json(xs: &[f32]) -> Json {
    Json::Arr(xs.iter().map(|&x| {
        if x.is_finite() { Json::Num(x as f64) } else { Json::Null }
    }).collect())
}

fn f32s_from_json(j: &Json) -> Result<Vec<f32>> {
    j.as_arr()?.iter().map(|v| match v {
        Json::Null => Ok(f32::NAN),
        other => Ok(other.as_f64()? as f32),
    }).collect()
}

/// Numeric seeds must stay strictly below 2^53: every such integer is an
/// exact f64, while anything at/above may already have been rounded by
/// the f64 parse (e.g. 2^53 + 1 arrives as exactly 2^53) — silently
/// changing the seed would break the bit-exact `Pcg64::new(seed)`
/// contract, so larger seeds travel as strings.
const NUM_SEED_LIMIT: u64 = 1 << 53;

fn parse_seed(j: &Json) -> Result<u64> {
    match j.get("seed") {
        None => Ok(0),
        Some(Json::Str(s)) => s.parse::<u64>()
            .map_err(|e| anyhow!("bad string seed {s:?}: {e}")),
        Some(v) => {
            let f = v.as_f64()?;
            if f < 0.0 || f.fract() != 0.0 {
                bail!("seed must be a non-negative integer");
            }
            if f >= NUM_SEED_LIMIT as f64 {
                bail!("numeric seed {f} is not exactly representable in \
                       JSON (>= 2^53); send it as a string: \
                       \"seed\":\"...\"");
            }
            Ok(f as u64)
        }
    }
}

fn seed_to_json(seed: u64) -> Json {
    if seed < NUM_SEED_LIMIT {
        Json::Num(seed as f64)
    } else {
        Json::Str(seed.to_string())
    }
}

fn opt_model(j: &Json) -> Result<Option<String>> {
    match j.get("model") {
        None => Ok(None),
        Some(m) => Ok(Some(m.as_str()?.to_string())),
    }
}

fn opt_cond(j: &Json) -> Result<Option<Tensor>> {
    match j.get("cond") {
        None => Ok(None),
        Some(c) => Ok(Some(tensor_from_json(c)?)),
    }
}

// ---------------------------------------------------------------------------
// Request (de)serialization
// ---------------------------------------------------------------------------

impl Request {
    /// Parse one JSON line into a request.
    pub fn parse_line(line: &str) -> Result<Request> {
        Request::from_json(&Json::parse(line)?)
    }

    pub fn from_json(j: &Json) -> Result<Request> {
        let op = j.req("op")?.as_str()?;
        match op {
            "sample" => {
                let n = match j.get("n") {
                    None => 1,
                    Some(v) => v.as_usize()?,
                };
                if n == 0 || n > MAX_SAMPLES_PER_REQUEST {
                    bail!("sample n must be in 1..={MAX_SAMPLES_PER_REQUEST}, \
                           got {n}");
                }
                let temperature = match j.get("temperature") {
                    None => 1.0,
                    Some(v) => v.as_f64()? as f32,
                };
                let seed = parse_seed(j)?;
                Ok(Request::Sample {
                    model: opt_model(j)?,
                    n,
                    temperature,
                    seed,
                    cond: opt_cond(j)?,
                })
            }
            "score" => Ok(Request::Score {
                model: opt_model(j)?,
                x: tensor_from_json(j.req("x")?)?,
                cond: opt_cond(j)?,
            }),
            "posterior" => {
                let y = f32s_from_json(j.req("y")?)?;
                if y.is_empty() || y.iter().any(|v| !v.is_finite()) {
                    bail!("posterior y must be a non-empty array of \
                           finite numbers");
                }
                let n = match j.get("n") {
                    None => 64,
                    Some(v) => v.as_usize()?,
                };
                if n == 0 || n > MAX_SAMPLES_PER_REQUEST {
                    bail!("posterior n must be in \
                           1..={MAX_SAMPLES_PER_REQUEST}, got {n}");
                }
                let temperature = match j.get("temperature") {
                    None => 1.0,
                    Some(v) => v.as_f64()? as f32,
                };
                let return_samples = match j.get("samples") {
                    None => false,
                    Some(Json::Bool(b)) => *b,
                    Some(other) => bail!("posterior samples flag must be \
                                          a bool, got {other:?}"),
                };
                Ok(Request::Posterior {
                    model: opt_model(j)?,
                    y,
                    n,
                    temperature,
                    seed: parse_seed(j)?,
                    return_samples,
                })
            }
            "stats" => Ok(Request::Stats),
            "metrics" => Ok(Request::Metrics),
            "debug-dump" => Ok(Request::DebugDump),
            "shutdown" => Ok(Request::Shutdown),
            other => bail!("unknown op {other:?} (sample|score|posterior\
                            |stats|metrics|debug-dump|shutdown)"),
        }
    }

    /// Serialize (for clients: tests, the bench harness).
    pub fn to_json(&self) -> Json {
        match self {
            Request::Sample { model, n, temperature, seed, cond } => {
                let mut pairs = vec![
                    ("op", Json::Str("sample".into())),
                    ("n", Json::Num(*n as f64)),
                    ("temperature", Json::Num(*temperature as f64)),
                    ("seed", seed_to_json(*seed)),
                ];
                if let Some(m) = model {
                    pairs.push(("model", Json::Str(m.clone())));
                }
                if let Some(c) = cond {
                    pairs.push(("cond", tensor_to_json(c)));
                }
                Json::obj(pairs)
            }
            Request::Score { model, x, cond } => {
                let mut pairs = vec![
                    ("op", Json::Str("score".into())),
                    ("x", tensor_to_json(x)),
                ];
                if let Some(m) = model {
                    pairs.push(("model", Json::Str(m.clone())));
                }
                if let Some(c) = cond {
                    pairs.push(("cond", tensor_to_json(c)));
                }
                Json::obj(pairs)
            }
            Request::Posterior { model, y, n, temperature, seed,
                                 return_samples } => {
                let mut pairs = vec![
                    ("op", Json::Str("posterior".into())),
                    ("y", f32s_to_json(y)),
                    ("n", Json::Num(*n as f64)),
                    ("temperature", Json::Num(*temperature as f64)),
                    ("seed", seed_to_json(*seed)),
                ];
                if *return_samples {
                    pairs.push(("samples", Json::Bool(true)));
                }
                if let Some(m) = model {
                    pairs.push(("model", Json::Str(m.clone())));
                }
                Json::obj(pairs)
            }
            Request::Stats => Json::obj(vec![("op", Json::Str("stats".into()))]),
            Request::Metrics => {
                Json::obj(vec![("op", Json::Str("metrics".into()))])
            }
            Request::DebugDump => {
                Json::obj(vec![("op", Json::Str("debug-dump".into()))])
            }
            Request::Shutdown => {
                Json::obj(vec![("op", Json::Str("shutdown".into()))])
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Response (de)serialization
// ---------------------------------------------------------------------------

impl Response {
    pub fn err(e: impl std::fmt::Display) -> Response {
        Response::Error { error: format!("{e}") }
    }

    pub fn is_error(&self) -> bool {
        matches!(self, Response::Error { .. })
    }

    pub fn to_json(&self) -> Json {
        match self {
            Response::Sample { x } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("op", Json::Str("sample".into())),
                ("x", tensor_to_json(x)),
            ]),
            Response::Score { log_density } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("op", Json::Str("score".into())),
                ("log_density", f32s_to_json(log_density)),
            ]),
            Response::Posterior { n, mean, std, samples } => {
                let mut pairs = vec![
                    ("ok", Json::Bool(true)),
                    ("op", Json::Str("posterior".into())),
                    ("n", Json::Num(*n as f64)),
                    ("mean", f32s_to_json(mean)),
                    ("std", f32s_to_json(std)),
                ];
                if let Some(x) = samples {
                    pairs.push(("x", tensor_to_json(x)));
                }
                Json::obj(pairs)
            }
            Response::Stats(s) => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("op", Json::Str("stats".into())),
                ("stats", Json::obj(vec![
                    ("requests", Json::Num(s.requests as f64)),
                    ("batches", Json::Num(s.batches as f64)),
                    ("items", Json::Num(s.items as f64)),
                    ("errors", Json::Num(s.errors as f64)),
                    ("mean_batch", Json::Num(s.mean_batch)),
                    ("mean_items", Json::Num(s.mean_items)),
                    ("p50_us", Json::Num(s.p50_us as f64)),
                    ("p99_us", Json::Num(s.p99_us as f64)),
                    ("p999_us", Json::Num(s.p999_us as f64)),
                    ("queue_depth", Json::Num(s.queue_depth as f64)),
                    ("models", Json::Num(s.models as f64)),
                ])),
            ]),
            Response::Metrics { text } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("op", Json::Str("metrics".into())),
                ("text", Json::Str(text.clone())),
            ]),
            Response::DebugDump { report } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("op", Json::Str("debug-dump".into())),
                ("report", report.clone()),
            ]),
            Response::Shutdown => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("op", Json::Str("shutdown".into())),
            ]),
            Response::Error { error } => Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::Str(error.clone())),
            ]),
        }
    }

    /// One wire frame (no trailing newline; the transport adds it).
    pub fn to_line(&self) -> String {
        self.to_json().to_string()
    }

    /// Parse a response line (for clients: tests, the bench harness).
    pub fn parse_line(line: &str) -> Result<Response> {
        let j = Json::parse(line)?;
        let ok = match j.req("ok")? {
            Json::Bool(b) => *b,
            other => bail!("bad ok field {other:?}"),
        };
        if !ok {
            return Ok(Response::Error {
                error: j.req("error")?.as_str()?.to_string(),
            });
        }
        match j.req("op")?.as_str()? {
            "sample" => Ok(Response::Sample {
                x: tensor_from_json(j.req("x")?)?,
            }),
            "score" => Ok(Response::Score {
                log_density: f32s_from_json(j.req("log_density")?)?,
            }),
            "posterior" => Ok(Response::Posterior {
                n: j.req("n")?.as_usize()?,
                mean: f32s_from_json(j.req("mean")?)?,
                std: f32s_from_json(j.req("std")?)?,
                samples: match j.get("x") {
                    None => None,
                    Some(x) => Some(tensor_from_json(x)?),
                },
            }),
            "shutdown" => Ok(Response::Shutdown),
            "stats" => {
                let s = j.req("stats")?;
                let u = |k: &str| -> Result<u64> {
                    Ok(s.req(k)?.as_f64()? as u64)
                };
                Ok(Response::Stats(StatsSnapshot {
                    requests: u("requests")?,
                    batches: u("batches")?,
                    items: u("items")?,
                    errors: u("errors")?,
                    mean_batch: s.req("mean_batch")?.as_f64()?,
                    mean_items: s.req("mean_items")?.as_f64()?,
                    p50_us: u("p50_us")?,
                    p99_us: u("p99_us")?,
                    p999_us: u("p999_us")?,
                    queue_depth: u("queue_depth")?,
                    models: u("models")?,
                }))
            }
            "metrics" => Ok(Response::Metrics {
                text: j.req("text")?.as_str()?.to_string(),
            }),
            "debug-dump" => Ok(Response::DebugDump {
                report: j.req("report")?.clone(),
            }),
            other => Err(anyhow!("unknown response op {other:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_request_roundtrip_with_defaults() {
        let r = Request::parse_line(r#"{"op":"sample"}"#).unwrap();
        assert_eq!(r, Request::Sample {
            model: None, n: 1, temperature: 1.0, seed: 0, cond: None,
        });
        let r = Request::parse_line(
            r#"{"op":"sample","n":4,"seed":9,"temperature":0.5,"model":"m"}"#,
        ).unwrap();
        let back = Request::from_json(&r.to_json()).unwrap();
        assert_eq!(r, back);
    }

    #[test]
    fn score_request_roundtrip() {
        let r = Request::parse_line(
            r#"{"op":"score","x":{"shape":[2,2],"data":[0.1,0.2,0.3,0.4]}}"#,
        ).unwrap();
        let Request::Score { x, .. } = &r else { panic!("not score") };
        assert_eq!(x.shape, vec![2, 2]);
        assert_eq!(Request::from_json(&r.to_json()).unwrap(), r);
    }

    #[test]
    fn rejects_bad_requests() {
        assert!(Request::parse_line("not json").is_err());
        assert!(Request::parse_line(r#"{"op":"frobnicate"}"#).is_err());
        assert!(Request::parse_line(r#"{"op":"sample","n":0}"#).is_err());
        assert!(Request::parse_line(r#"{"op":"sample","seed":-1}"#).is_err());
        assert!(Request::parse_line(r#"{"op":"score"}"#).is_err());
        assert!(Request::parse_line(
            r#"{"op":"score","x":{"shape":[2,3],"data":[1]}}"#).is_err());
    }

    #[test]
    fn posterior_request_roundtrip_and_validation() {
        let r = Request::parse_line(
            r#"{"op":"posterior","y":[0.7,-0.4]}"#).unwrap();
        assert_eq!(r, Request::Posterior {
            model: None, y: vec![0.7, -0.4], n: 64, temperature: 1.0,
            seed: 0, return_samples: false,
        });
        let r = Request::parse_line(
            r#"{"op":"posterior","y":[1.5],"n":8,"seed":3,"samples":true,
                "temperature":0.5,"model":"m"}"#).unwrap();
        assert_eq!(Request::from_json(&r.to_json()).unwrap(), r);
        let Request::Posterior { return_samples, .. } = r else { panic!() };
        assert!(return_samples);

        // missing / empty / non-finite y, bad n, bad samples flag
        assert!(Request::parse_line(r#"{"op":"posterior"}"#).is_err());
        assert!(Request::parse_line(
            r#"{"op":"posterior","y":[]}"#).is_err());
        assert!(Request::parse_line(
            r#"{"op":"posterior","y":[1.0,null]}"#).is_err());
        assert!(Request::parse_line(
            r#"{"op":"posterior","y":[1.0],"n":0}"#).is_err());
        assert!(Request::parse_line(
            r#"{"op":"posterior","y":[1.0],"samples":"yes"}"#).is_err());
    }

    #[test]
    fn posterior_response_roundtrip() {
        let with = Response::Posterior {
            n: 3,
            mean: vec![0.25, -1.5],
            std: vec![0.5, 0.125],
            samples: Some(Tensor::new(vec![3, 2],
                                      vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6])
                          .unwrap()),
        };
        assert_eq!(Response::parse_line(&with.to_line()).unwrap(), with);
        let without = Response::Posterior {
            n: 3, mean: vec![0.25], std: vec![0.5], samples: None,
        };
        let line = without.to_line();
        assert!(!line.contains("\"x\""), "{line}");
        assert_eq!(Response::parse_line(&line).unwrap(), without);
    }

    #[test]
    fn seeds_beyond_2_pow_53_travel_as_strings() {
        // a numeric seed above 2^53 would be silently rounded by f64 —
        // the parser refuses it and points at the string form
        let err = Request::parse_line(
            r#"{"op":"sample","seed":9007199254740993}"#).unwrap_err();
        assert!(format!("{err:#}").contains("string"), "{err:#}");

        let big = u64::MAX - 12345;
        let r = Request::Sample {
            model: None, n: 1, temperature: 1.0, seed: big, cond: None,
        };
        let line = r.to_json().to_string();
        assert!(line.contains(&format!("\"{big}\"")), "{line}");
        assert_eq!(Request::parse_line(&line).unwrap(), r);

        // small seeds keep the plain numeric form
        let r = Request::parse_line(r#"{"op":"sample","seed":7}"#).unwrap();
        let Request::Sample { seed, .. } = r else { panic!() };
        assert_eq!(seed, 7);
        assert!(Request::parse_line(
            r#"{"op":"sample","seed":"not-a-number"}"#).is_err());
    }

    #[test]
    fn f32_payloads_survive_the_wire_bit_exactly() {
        // awkward values: subnormal-ish, many mantissa bits, negatives
        let xs = vec![0.1f32, -1.0 / 3.0, 1e-38, 123456.789, -0.0,
                      f32::MIN_POSITIVE, 1.0000001];
        let t = Tensor::new(vec![7], xs.clone()).unwrap();
        let line = Response::Sample { x: t }.to_line();
        let Response::Sample { x } = Response::parse_line(&line).unwrap()
        else { panic!() };
        for (a, b) in xs.iter().zip(&x.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        }
    }

    #[test]
    fn non_finite_scores_cross_as_null() {
        let line = Response::Score {
            log_density: vec![1.5, f32::NEG_INFINITY, f32::NAN],
        }.to_line();
        assert!(line.contains("null"));
        let Response::Score { log_density } =
            Response::parse_line(&line).unwrap() else { panic!() };
        assert_eq!(log_density[0], 1.5);
        assert!(log_density[1].is_nan() && log_density[2].is_nan());
    }

    #[test]
    fn stats_and_shutdown_roundtrip() {
        let s = StatsSnapshot {
            requests: 10, batches: 3, items: 24, errors: 1,
            mean_batch: 10.0 / 3.0, mean_items: 8.0,
            p50_us: 120, p99_us: 900, p999_us: 2100, queue_depth: 0,
            models: 2,
        };
        let back = Response::parse_line(&Response::Stats(s.clone()).to_line())
            .unwrap();
        assert_eq!(back, Response::Stats(s));
        assert_eq!(
            Response::parse_line(&Response::Shutdown.to_line()).unwrap(),
            Response::Shutdown);
        let e = Response::err("boom");
        assert!(Response::parse_line(&e.to_line()).unwrap().is_error());
    }

    #[test]
    fn req_meta_parses_trace_id_and_timing_flag() {
        let j = Json::parse(r#"{"op":"stats"}"#).unwrap();
        assert_eq!(ReqMeta::from_json(&j).unwrap(), ReqMeta::default());

        let j = Json::parse(
            r#"{"op":"sample","trace_id":"cli-42","timing":true}"#).unwrap();
        let m = ReqMeta::from_json(&j).unwrap();
        assert_eq!(m.trace_id.as_deref(), Some("cli-42"));
        assert!(m.timing);
        // meta fields never confuse the op parser
        Request::from_json(&j).unwrap();

        for bad in [
            r#"{"trace_id":""}"#,
            r#"{"trace_id":7}"#,
            r#"{"trace_id":"a\nb"}"#,
            r#"{"timing":"yes"}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(ReqMeta::from_json(&j).is_err(), "{bad}");
        }
        let long = format!(r#"{{"trace_id":"{}"}}"#, "x".repeat(129));
        assert!(ReqMeta::from_json(&Json::parse(&long).unwrap()).is_err());
    }

    #[test]
    fn debug_dump_op_roundtrips() {
        assert_eq!(Request::parse_line(r#"{"op":"debug-dump"}"#).unwrap(),
                   Request::DebugDump);
        assert_eq!(
            Request::from_json(&Request::DebugDump.to_json()).unwrap(),
            Request::DebugDump);
        let r = Response::DebugDump {
            report: Json::obj(vec![
                ("schema", Json::Str("invertnet-dump/v1".into())),
                ("events", Json::Arr(vec![])),
            ]),
        };
        assert_eq!(Response::parse_line(&r.to_line()).unwrap(), r);
    }

    #[test]
    fn decorate_adds_keys_without_touching_payload_fields() {
        let resp = Response::Score { log_density: vec![1.5, -2.25] };
        let plain = resp.to_json();
        let timing = Timing { parse_us: 3, total_us: 40, ..Timing::default() };
        let deco = decorate(resp.to_json(), Some("t-1"), Some(&timing));
        assert_eq!(deco.req("trace_id").unwrap().as_str().unwrap(), "t-1");
        let t = deco.req("timing").unwrap();
        assert_eq!(t.req("parse_us").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(t.req("total_us").unwrap().as_f64().unwrap(), 40.0);
        // every payload field serializes to the same bytes with and
        // without decoration — the tracing bit-invisibility contract
        for key in ["ok", "op", "log_density"] {
            assert_eq!(plain.req(key).unwrap().to_string(),
                       deco.req(key).unwrap().to_string(), "{key}");
        }
        // decorated lines still parse as the same response
        assert_eq!(Response::parse_line(&deco.to_string()).unwrap(), resp);
    }

    #[test]
    fn metrics_op_roundtrips_exposition_text() {
        assert_eq!(Request::parse_line(r#"{"op":"metrics"}"#).unwrap(),
                   Request::Metrics);
        assert_eq!(
            Request::from_json(&Request::Metrics.to_json()).unwrap(),
            Request::Metrics);
        // newlines and quotes in the exposition body must survive the
        // JSON string escaping on the wire
        let r = Response::Metrics {
            text: "# TYPE a_total counter\na_total 1\n\
                   a_bucket{le=\"3\"} 2\n".to_string(),
        };
        assert_eq!(Response::parse_line(&r.to_line()).unwrap(), r);
    }
}
