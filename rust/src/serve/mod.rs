//! `serve` — the batched inference-serving subsystem: turn trained
//! checkpoints into a long-lived, concurrent service for the paper's
//! amortized-inference workload (many small conditional sampling / scoring
//! requests against one trained flow).
//!
//! ```text
//!                 ┌──────────────┐   JSON lines    ┌─────────────────┐
//!  clients ──────▶│ tcp / stdio  │────────────────▶│ Server::handle  │
//!                 └──────────────┘                 └──────┬──────────┘
//!                                                         │ submit
//!                 ┌──────────────┐    LRU get      ┌──────▼──────────┐
//!                 │   Registry   │◀────────────────│    Batcher      │
//!                 │ (Flow,Params)│                 │ coalesce + pool │
//!                 └──────────────┘                 └─────────────────┘
//! ```
//!
//! * [`registry::Registry`] — loads/caches `(Flow, ParamStore)` pairs from
//!   checkpoint directories, LRU-capped, warm-able at startup.
//! * [`batcher::Batcher`] — coalesces single-item `sample`/`score`
//!   requests into one batched inverse/forward pass (deadline- and
//!   max-batch-triggered, bounded-queue backpressure), executed by a
//!   worker pool of [`crate::Flow::fork`] handles. The `posterior` op
//!   rides the same sample path: its tiled-cond inversion coalesces with
//!   ordinary sample requests for the same model.
//! * [`server::Server`] — the transport-agnostic request core plus the
//!   loopback TCP and stdio fronts.
//! * [`protocol`] — the JSON-lines request/response frames.
//!
//! Micro-batching is **invisible**: every layer program is
//! batch-elementwise, so a coalesced response is bit-identical to a direct
//! [`crate::Flow::sample`] / [`crate::Flow::log_density`] call
//! (pinned in `tests/serve.rs`). CLI entry points:
//!
//! ```text
//! invertnet serve --ckpt runs/moons/checkpoint --stdio
//! invertnet serve --ckpt runs/moons/checkpoint --port 7878 \
//!                 --max-batch 16 --max-delay-us 300 --workers 4
//! invertnet score --ckpt runs/moons/checkpoint --data x.npy --out scores.npy
//! ```

pub mod batcher;
pub mod protocol;
pub mod registry;
pub mod server;

pub use batcher::{BatchConfig, BatchTimes, Batcher, ReplyPayload, ServeStats};
pub use protocol::{decorate, ReqMeta, Request, Response, StatsSnapshot, Timing};
pub use registry::{Registry, ServedModel};
pub use server::Server;
