//! Minimal, dependency-free subset of the `anyhow` API, vendored because the
//! build environment is offline. Implements exactly what this workspace
//! uses: [`Error`], [`Result`], [`Context`], and the `anyhow!` / `bail!` /
//! `ensure!` macros.
//!
//! Semantics mirror upstream anyhow where it matters here:
//! * `Display` prints the outermost message only;
//! * alternate display (`{:#}`) prints the whole context chain joined by
//!   `": "` (outermost first);
//! * `Debug` prints the outermost message plus a `Caused by:` list;
//! * any `std::error::Error + Send + Sync + 'static` converts via `?`.

use std::fmt;

/// An error chain: `chain[0]` is the outermost (most recent) message.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<M: fmt::Display>(mut self, message: M) -> Error {
        self.chain.insert(0, message.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like upstream anyhow, `Error` deliberately does NOT implement
// `std::error::Error`; that is what makes the blanket `From` below coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<M: fmt::Display>(self, message: M) -> Result<T>;
    fn with_context<M: fmt::Display, F: FnOnce() -> M>(self, f: F) -> Result<T>;
}

// A single blanket over `E: Into<Error>` covers both `Result<T, Error>`
// (reflexive conversion) and `Result<T, E>` for any std error (via the
// `From` impl above) with zero impl overlap.
impl<T, E> Context<T> for Result<T, E>
where
    E: Into<Error>,
{
    fn context<M: fmt::Display>(self, message: M) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(message)
        })
    }

    fn with_context<M: fmt::Display, F: FnOnce() -> M>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<M: fmt::Display>(self, message: M) -> Result<T> {
        self.ok_or_else(|| Error::msg(message))
    }

    fn with_context<M: fmt::Display, F: FnOnce() -> M>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Create an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("inner {}", 42)
    }

    #[test]
    fn display_and_chain() {
        let e = fails().with_context(|| "outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 42");
        assert!(format!("{e:?}").contains("Caused by"));
        assert_eq!(e.root_cause(), "inner 42");
    }

    #[test]
    fn from_std_error() {
        fn io() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        let e = io().context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        assert!(format!("{e:#}").contains("reading config: "));
    }

    #[test]
    fn ensure_works() {
        fn check(v: i32) -> Result<i32> {
            ensure!(v > 0, "not positive: {v}");
            Ok(v)
        }
        assert!(check(1).is_ok());
        assert!(check(-1).is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3).context("missing").unwrap(), 3);
    }
}
