//! Offline **stub** of the `xla` (xla-rs) PJRT API surface that
//! `invertnet`'s `XlaBackend` compiles against.
//!
//! The build image does not ship the XLA extension, so this crate exists to
//! (a) keep `--features xla` building hermetically and (b) document exactly
//! which PJRT entry points the backend needs. Every runtime constructor
//! returns an error; the value-carrying types are backed by an uninhabited
//! `Void`, so post-construction methods are statically unreachable.
//!
//! To run against real PJRT, replace this path dependency with an actual
//! xla-rs checkout exposing the same items (see `rust/src/backend/xla.rs`).

/// Uninhabited marker: stub objects can never be constructed.
#[derive(Debug, Clone, Copy)]
enum Void {}

/// Error type matching xla-rs's `Error` shape closely enough for `{e:?}`.
#[derive(Debug)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: the xla runtime is not vendored in this build; \
         point the `xla` path dependency at a real xla-rs checkout"
    ))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    F64,
    S32,
    S64,
}

/// Host literal (stub).
#[derive(Debug)]
pub struct Literal(Void);

/// Array shape metadata (stub).
#[derive(Debug)]
pub struct ArrayShape(Void);

impl ArrayShape {
    pub fn dims(&self) -> Vec<i64> {
        match self.0 {}
    }
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(unavailable("Literal::create_from_shape_and_untyped_data"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self.0 {}
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        match self.0 {}
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match self.0 {}
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto(Void);

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation handle (stub).
#[derive(Debug)]
pub struct XlaComputation(Void);

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        match proto.0 {}
    }
}

/// Device buffer returned by execution (stub).
#[derive(Debug)]
pub struct PjRtBuffer(Void);

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match self.0 {}
    }
}

/// Compiled executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(Void);

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        match self.0 {}
    }
}

/// PJRT client (stub): construction reports the missing runtime.
#[derive(Debug)]
pub struct PjRtClient(Void);

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        match self.0 {}
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        match self.0 {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_report_missing_runtime() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.0.contains("not vendored"));
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::F32, &[2], &[0; 8]).is_err());
    }
}
