#!/usr/bin/env python3
"""Validate the JSON-lines transcripts the CI smoke steps capture.

Usage:
    python3 scripts/ci_smoke.py serve     /tmp/serve_out.jsonl
    python3 scripts/ci_smoke.py posterior /tmp/post_serve.jsonl
    python3 scripts/ci_smoke.py bench     BENCH_quick.json
    python3 scripts/ci_smoke.py lint      /tmp/lint_catalog.json
    python3 scripts/ci_smoke.py lint      /tmp/lint_bad.json expect-errors
    python3 scripts/ci_smoke.py metrics   /tmp/train_metrics.prom
    python3 scripts/ci_smoke.py dump      /tmp/debug_dump.json
    python3 scripts/ci_smoke.py events    /tmp/events.jsonl
    python3 scripts/ci_smoke.py rpc       127.0.0.1:7878 '{"op":"stats"}'
    python3 scripts/ci_smoke.py http      127.0.0.1:7878 /readyz 503

Each suite checks one kind of artifact:

* ``serve``     — a stdio serve session transcript: traced sample +
                  score + stats + metrics + debug-dump + shutdown, all
                  ok, with the trace-id echoed verbatim, the timing
                  block present, and the batcher/queue/phase series in
                  the metrics reply.
* ``posterior`` — a posterior-op serve transcript: one posterior reply
                  (mean/std/samples) + shutdown.
* ``bench``     — a ``BENCH_<suite>.json`` document: schema tag, the
                  environment block, and at least one gated metric.
* ``lint``      — an ``invertnet lint --json`` report: schema tag and
                  per-network diagnostics. The default expects a clean
                  catalog; pass ``expect-errors`` as a third argument to
                  assert the report carries machine-readable diagnostics
                  (the malformed-manifest smoke).
* ``metrics``   — a ``--metrics-out`` dump from ``train``: well-formed
                  Prometheus text exposition carrying the required train
                  and span series.
* ``dump``      — an ``{"op":"debug-dump"}`` reply (or a bare
                  ``invertnet-dump/v1`` report): schema tag, event list,
                  emit/drop totals.
* ``events``    — a ``--log-json`` file: every line a well-formed
                  ``invertnet-event/v1`` record (dump lines allowed).
* ``rpc``       — connect to a JSON-lines TCP server, send one request
                  line, print the reply to stdout (the CI TCP smoke's
                  transport; asserts the reply is one JSON line).
* ``http``      — issue ``GET PATH`` against the serve front, assert
                  the status code matches, print the body.

Exit code 0 on success; an AssertionError message names what broke.
(Replaces the inline ``python3 -c`` heredocs that used to live in
.github/workflows/ci.yml — a checked-in script is diffable, lintable,
and shared between the smoke steps.)
"""

import json
import math
import socket
import sys


def load_lines(path):
    with open(path) as fh:
        return [json.loads(line) for line in fh if line.strip()]


def parse_exposition(text):
    """Validate Prometheus text exposition; return {family: kind}.

    Mirrors the shape rules of the Rust parser
    (rust/src/telemetry/encode.rs::parse_exposition): every sample
    belongs to a declared family, every value is finite (NaN rejected),
    counters are non-negative, series are unique, histogram buckets are
    well-formed — ``le`` bounds strictly increasing, counts cumulative,
    the ``le="+Inf"`` bucket present, last, and equal to ``_count`` —
    and every family has at least one sample.
    """
    families = {}
    counts = {}
    seen_series = set()
    current = None
    hist = None  # {"buckets": [(le, cum)], "inf": .., "sum": .., "count": ..}

    def close_hist():
        if hist is None:
            return
        name, h = hist["name"], hist
        assert h["inf"] is not None, \
            f'histogram {name}: missing le="+Inf" bucket'
        assert h["sum"] is not None and h["count"] is not None, \
            f"histogram {name}: missing _sum or _count"
        assert h["inf"] == h["count"], (
            f'histogram {name}: le="+Inf" bucket {h["inf"]} disagrees '
            f'with _count {h["count"]}')
        if h["buckets"]:
            last = h["buckets"][-1][1]
            assert last <= h["inf"], (
                f'histogram {name}: bucket count {last} exceeds '
                f'le="+Inf" count {h["inf"]}')

    def sample_value(lineno, raw):
        try:
            v = float(raw)
        except ValueError:
            raise AssertionError(
                f"line {lineno}: unparsable sample value {raw!r}")
        assert not math.isnan(v), f"line {lineno}: NaN sample value"
        return v

    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.rstrip()
        if not line:
            continue
        if line.startswith("# TYPE "):
            close_hist()
            hist = None
            parts = line[len("# TYPE "):].split()
            assert len(parts) == 2, f"line {lineno}: bad TYPE line {line!r}"
            name, kind = parts
            assert kind in ("counter", "gauge", "histogram"), \
                f"line {lineno}: unknown metric kind {kind!r}"
            assert name not in families, \
                f"line {lineno}: duplicate family {name!r}"
            families[name] = kind
            counts[name] = 0
            current = name
            if kind == "histogram":
                hist = {"name": name, "buckets": [], "inf": None,
                        "sum": None, "count": None}
            continue
        if line.startswith("#"):
            continue
        series, sep, value = line.rpartition(" ")
        assert series and sep, \
            f"line {lineno}: sample line has no value: {line!r}"
        assert current is not None, \
            f"line {lineno}: sample before any TYPE line: {line!r}"
        v = sample_value(lineno, value)
        name = series.split("{")[0]
        if families[current] != "histogram":
            assert name == current, (
                f"line {lineno}: sample {name!r} does not belong to "
                f"family {current!r}")
            assert math.isfinite(v), \
                f"line {lineno}: non-finite {families[current]} value {v}"
            if families[current] == "counter":
                assert v >= 0, f"line {lineno}: negative counter value {v}"
            assert series not in seen_series, \
                f"line {lineno}: duplicate series {series!r}"
            seen_series.add(series)
        elif name == f"{current}_bucket":
            rest = series[len(name):]
            assert rest.startswith('{le="') and rest.endswith('"}'), \
                f"line {lineno}: malformed bucket line {line!r}"
            le_str = rest[len('{le="'):-len('"}')]
            assert math.isfinite(v) and v >= 0, \
                f"line {lineno}: negative or non-finite bucket count {v}"
            if le_str == "+Inf":
                assert hist["inf"] is None, \
                    f'line {lineno}: duplicate le="+Inf" bucket'
                if hist["buckets"]:
                    assert v >= hist["buckets"][-1][1], \
                        f"line {lineno}: non-cumulative bucket counts"
                hist["inf"] = v
            else:
                try:
                    le = float(le_str)
                except ValueError:
                    raise AssertionError(
                        f"line {lineno}: malformed bucket line {line!r}")
                assert hist["inf"] is None, \
                    f'line {lineno}: bucket after the le="+Inf" bucket'
                if hist["buckets"]:
                    prev_le, prev_cum = hist["buckets"][-1]
                    assert le > prev_le, \
                        f"line {lineno}: bucket bounds out of order"
                    assert v >= prev_cum, \
                        f"line {lineno}: non-cumulative bucket counts"
                hist["buckets"].append((le, v))
        elif series == f"{current}_sum":
            assert math.isfinite(v) and v >= 0, \
                f"line {lineno}: negative or non-finite histogram _sum {v}"
            assert hist["sum"] is None, \
                f"line {lineno}: duplicate series {series!r}"
            hist["sum"] = v
        elif series == f"{current}_count":
            assert math.isfinite(v) and v >= 0, \
                f"line {lineno}: negative or non-finite histogram _count {v}"
            assert hist["count"] is None, \
                f"line {lineno}: duplicate series {series!r}"
            hist["count"] = v
        else:
            raise AssertionError(
                f"line {lineno}: sample {name!r} does not belong to "
                f"family {current!r}")
        counts[current] += 1
    close_hist()
    assert families, "no metric families found"
    empties = [n for n, c in counts.items() if c == 0]
    assert not empties, f"families with no samples: {empties}"
    return families


TIMING_KEYS = ("parse_us", "validate_us", "queue_wait_us",
               "batch_assembly_us", "execute_us", "total_us",
               "batch_jobs", "batch_rows")


def check_serve(path):
    resp = load_lines(path)
    assert len(resp) == 6, f"expected 6 replies, got {len(resp)}: {resp}"
    assert all(r["ok"] for r in resp), resp
    # reply 0: traced sample — trace id echoed verbatim, timing attached
    assert resp[0]["x"]["shape"] == [2, 2], resp[0]
    assert resp[0]["trace_id"] == "ci-trace-1", resp[0]
    timing = resp[0]["timing"]
    for key in TIMING_KEYS:
        assert key in timing, f"timing block missing {key!r}: {timing}"
    assert timing["batch_rows"] >= 2, timing
    # reply 1: plain score — no decoration on an undecorated request
    assert len(resp[1]["log_density"]) == 2, resp[1]
    assert "trace_id" not in resp[1] and "timing" not in resp[1], resp[1]
    assert resp[2]["stats"]["requests"] == 2, resp[2]
    assert "p999_us" in resp[2]["stats"], resp[2]
    scrape = resp[3]["text"]
    families = parse_exposition(scrape)
    for series in ("invertnet_serve_requests_total",
                   "invertnet_serve_batches_total",
                   "invertnet_serve_queue_depth",
                   "invertnet_serve_batch_rows",
                   "invertnet_serve_sample_latency_us",
                   "invertnet_serve_score_latency_us",
                   "invertnet_serve_phase_parse_us",
                   "invertnet_serve_phase_queue_wait_us",
                   "invertnet_serve_phase_execute_us"):
        assert series in families, f"{series} missing from metrics reply"
    check_dump_doc(resp[4]["report"])
    assert resp[5].get("op") == "shutdown", resp[5]


def check_metrics(path):
    with open(path) as fh:
        families = parse_exposition(fh.read())
    for series in ("invertnet_train_steps_total",
                   "invertnet_train_loss",
                   "invertnet_train_grad_norm",
                   "invertnet_train_peak_sched_bytes",
                   "invertnet_span_train_step_us"):
        assert series in families, f"{series} missing from {path}"
    assert families["invertnet_train_steps_total"] == "counter", families
    assert families["invertnet_span_train_step_us"] == "histogram", families


def check_posterior(path):
    resp = load_lines(path)
    assert len(resp) == 2, f"expected 2 replies, got {len(resp)}: {resp}"
    assert all(r["ok"] for r in resp), resp
    post = resp[0]
    assert post["n"] == 32, post
    assert len(post["mean"]) == 2 and len(post["std"]) == 2, post
    assert all(s > 0 for s in post["std"]), post
    assert post["x"]["shape"] == [32, 2], post


def check_bench(path):
    with open(path) as fh:
        doc = json.load(fh)
    assert doc["schema"] == "invertnet-bench/v1", doc.get("schema")
    env = doc["env"]
    for key in ("git_rev", "threads", "cpus", "profile", "backend"):
        assert key in env, f"env block missing {key!r}: {env}"
    metrics = doc["metrics"]
    assert metrics, "no metrics recorded"
    gated = [m for m in metrics if m["check"]]
    assert gated, "no gated metrics — the regression gate would be empty"
    for m in metrics:
        assert isinstance(m["value"], (int, float)), m


def check_lint(path, expect="clean"):
    assert expect in ("clean", "expect-errors"), f"bad mode {expect!r}"
    with open(path) as fh:
        doc = json.load(fh)
    assert doc["schema"] == "invertnet-lint/v2", doc.get("schema")
    nets = doc["networks"]
    assert nets, "lint report covers no networks"
    for n in nets:
        for key in ("name", "ok", "diagnostics", "peaks", "cost"):
            assert key in n, f"network entry missing {key!r}: {n}"
    if expect == "expect-errors":
        assert doc["errors"] > 0, "malformed manifest produced no errors"
        diags = [d for n in nets for d in n["diagnostics"]]
        assert diags, "errors counted but no diagnostics recorded"
        for d in diags:
            assert d["severity"] in ("error", "warning"), d
            assert d["code"] and d["message"], d
    else:
        assert doc["errors"] == 0, f"catalog lint found errors: {doc}"
        assert all(n["ok"] for n in nets), nets
        # clean networks must carry the v2 cost block: positive train
        # flops per schedule, stored cheapest, invertible costliest
        for n in nets:
            cost = n["cost"]
            assert cost, f"clean network {n['name']} has no cost block"
            train = cost["train"]
            assert set(train) == {"invertible", "stored",
                                  "checkpoint_every_4"}, train
            for label, t in train.items():
                assert t["flops"] > 0 and t["bytes"] > 0, (label, t)
            assert train["stored"]["flops"] <= \
                train["checkpoint_every_4"]["flops"] <= \
                train["invertible"]["flops"], train
            assert 0 < cost["inference_flops"] < \
                train["stored"]["flops"], cost
            assert cost["sample_flops"] > 0, cost


def check_event_doc(e):
    assert e["schema"] == "invertnet-event/v1", e
    assert e["level"] in ("info", "warn", "error"), e
    assert e["kind"], e
    assert e["seq"] >= 1 and e["ts_ms"] > 0, e


def check_dump_doc(doc):
    assert doc["schema"] == "invertnet-dump/v1", doc.get("schema")
    assert doc["reason"], doc
    assert isinstance(doc["events"], list), doc
    for e in doc["events"]:
        check_event_doc(e)
    assert doc["emitted_total"] >= len(doc["events"]), doc
    assert doc["dropped_total"] >= 0, doc


def check_dump(path):
    with open(path) as fh:
        doc = json.loads(fh.readline())
    # accept either a protocol reply carrying the report, or a bare report
    if "report" in doc:
        assert doc["ok"] and doc.get("op") == "debug-dump", doc
        doc = doc["report"]
    check_dump_doc(doc)


def check_events(path):
    lines = load_lines(path)
    assert lines, f"{path} holds no events"
    kinds = set()
    for e in lines:
        if e.get("schema") == "invertnet-dump/v1":
            check_dump_doc(e)  # emit_dump lines ride the same file
            continue
        check_event_doc(e)
        kinds.add(e["kind"])
    assert kinds, f"{path} holds only dump lines"


def rpc(addr, request):
    json.loads(request)  # the request itself must be valid JSON
    host, _, port = addr.rpartition(":")
    with socket.create_connection((host, int(port)), timeout=30) as s:
        s.sendall(request.encode() + b"\n")
        fh = s.makefile("r", encoding="utf-8")
        line = fh.readline().strip()
    assert line, f"no reply from {addr}"
    json.loads(line)  # reply must be one valid JSON line
    print(line)


def http(addr, path, expect_status):
    host, _, port = addr.rpartition(":")
    with socket.create_connection((host, int(port)), timeout=30) as s:
        s.sendall(f"GET {path} HTTP/1.0\r\n\r\n".encode())
        raw = b""
        while True:
            chunk = s.recv(65536)
            if not chunk:
                break
            raw += chunk
    head, sep, body = raw.decode().partition("\r\n\r\n")
    assert sep, f"malformed HTTP response from {addr}{path}: {raw!r}"
    status = head.splitlines()[0]
    assert f" {expect_status} " in status + " ", \
        f"{addr}{path}: expected {expect_status}, got {status!r}"
    assert "Connection: close" in head, head
    sys.stdout.write(body)


CHECKS = {"serve": check_serve, "posterior": check_posterior,
          "bench": check_bench, "lint": check_lint,
          "metrics": check_metrics, "dump": check_dump,
          "events": check_events, "rpc": rpc, "http": http}

# mode -> (min args after the mode, max args after the mode)
ARITY = {"lint": (1, 2), "rpc": (2, 2), "http": (3, 3)}


def main(argv):
    mode = argv[1] if len(argv) > 1 else ""
    lo, hi = ARITY.get(mode, (1, 1))
    if mode not in CHECKS or not lo <= len(argv) - 2 <= hi:
        sys.stderr.write(__doc__)
        return 2
    CHECKS[mode](*argv[2:])
    if mode not in ("rpc", "http"):
        print(f"ci_smoke {mode}: {argv[2]} ok")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
