#!/usr/bin/env python3
"""Validate the JSON-lines transcripts the CI smoke steps capture.

Usage:
    python3 scripts/ci_smoke.py serve     /tmp/serve_out.jsonl
    python3 scripts/ci_smoke.py posterior /tmp/post_serve.jsonl
    python3 scripts/ci_smoke.py bench     BENCH_quick.json
    python3 scripts/ci_smoke.py lint      /tmp/lint_catalog.json
    python3 scripts/ci_smoke.py lint      /tmp/lint_bad.json expect-errors
    python3 scripts/ci_smoke.py metrics   /tmp/train_metrics.prom

Each suite checks one kind of artifact:

* ``serve``     — a stdio serve session transcript: sample + score +
                  stats + metrics + shutdown, all ok, with the expected
                  shapes and the batcher/queue series in the metrics
                  reply.
* ``posterior`` — a posterior-op serve transcript: one posterior reply
                  (mean/std/samples) + shutdown.
* ``bench``     — a ``BENCH_<suite>.json`` document: schema tag, the
                  environment block, and at least one gated metric.
* ``lint``      — an ``invertnet lint --json`` report: schema tag and
                  per-network diagnostics. The default expects a clean
                  catalog; pass ``expect-errors`` as a third argument to
                  assert the report carries machine-readable diagnostics
                  (the malformed-manifest smoke).
* ``metrics``   — a ``--metrics-out`` dump from ``train``: well-formed
                  Prometheus text exposition carrying the required train
                  and span series.

Exit code 0 on success; an AssertionError message names what broke.
(Replaces the inline ``python3 -c`` heredocs that used to live in
.github/workflows/ci.yml — a checked-in script is diffable, lintable,
and shared between the smoke steps.)
"""

import json
import sys


def load_lines(path):
    with open(path) as fh:
        return [json.loads(line) for line in fh if line.strip()]


def parse_exposition(text):
    """Validate Prometheus text exposition; return {family: kind}.

    Mirrors the shape rules of the Rust parser
    (rust/src/telemetry/encode.rs::parse_exposition): every sample
    belongs to a declared family, every value parses, every family has
    at least one sample.
    """
    families = {}
    counts = {}
    current = None
    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.rstrip()
        if not line:
            continue
        if line.startswith("# TYPE "):
            parts = line[len("# TYPE "):].split()
            assert len(parts) == 2, f"line {lineno}: bad TYPE line {line!r}"
            name, kind = parts
            assert kind in ("counter", "gauge", "histogram"), (lineno, kind)
            assert name not in families, f"line {lineno}: dup family {name}"
            families[name] = kind
            counts[name] = 0
            current = name
            continue
        if line.startswith("#"):
            continue
        series, _, value = line.rpartition(" ")
        assert series, f"line {lineno}: sample has no value: {line!r}"
        float(value)  # raises on malformed values
        name = series.split("{")[0]
        assert current is not None, f"line {lineno}: sample before TYPE"
        ok = name == current or (
            families[current] == "histogram"
            and name in (f"{current}_bucket", f"{current}_sum",
                         f"{current}_count"))
        assert ok, f"line {lineno}: {name!r} outside family {current!r}"
        counts[current] += 1
    assert families, "no metric families found"
    empties = [n for n, c in counts.items() if c == 0]
    assert not empties, f"families with no samples: {empties}"
    return families


def check_serve(path):
    resp = load_lines(path)
    assert len(resp) == 5, f"expected 5 replies, got {len(resp)}: {resp}"
    assert all(r["ok"] for r in resp), resp
    assert resp[0]["x"]["shape"] == [2, 2], resp[0]
    assert len(resp[1]["log_density"]) == 2, resp[1]
    assert resp[2]["stats"]["requests"] == 2, resp[2]
    assert "p999_us" in resp[2]["stats"], resp[2]
    scrape = resp[3]["text"]
    families = parse_exposition(scrape)
    for series in ("invertnet_serve_requests_total",
                   "invertnet_serve_batches_total",
                   "invertnet_serve_queue_depth",
                   "invertnet_serve_batch_rows",
                   "invertnet_serve_sample_latency_us",
                   "invertnet_serve_score_latency_us"):
        assert series in families, f"{series} missing from metrics reply"


def check_metrics(path):
    with open(path) as fh:
        families = parse_exposition(fh.read())
    for series in ("invertnet_train_steps_total",
                   "invertnet_train_loss",
                   "invertnet_train_grad_norm",
                   "invertnet_train_peak_sched_bytes",
                   "invertnet_span_train_step_us"):
        assert series in families, f"{series} missing from {path}"
    assert families["invertnet_train_steps_total"] == "counter", families
    assert families["invertnet_span_train_step_us"] == "histogram", families


def check_posterior(path):
    resp = load_lines(path)
    assert len(resp) == 2, f"expected 2 replies, got {len(resp)}: {resp}"
    assert all(r["ok"] for r in resp), resp
    post = resp[0]
    assert post["n"] == 32, post
    assert len(post["mean"]) == 2 and len(post["std"]) == 2, post
    assert all(s > 0 for s in post["std"]), post
    assert post["x"]["shape"] == [32, 2], post


def check_bench(path):
    with open(path) as fh:
        doc = json.load(fh)
    assert doc["schema"] == "invertnet-bench/v1", doc.get("schema")
    env = doc["env"]
    for key in ("git_rev", "threads", "cpus", "profile", "backend"):
        assert key in env, f"env block missing {key!r}: {env}"
    metrics = doc["metrics"]
    assert metrics, "no metrics recorded"
    gated = [m for m in metrics if m["check"]]
    assert gated, "no gated metrics — the regression gate would be empty"
    for m in metrics:
        assert isinstance(m["value"], (int, float)), m


def check_lint(path, expect="clean"):
    assert expect in ("clean", "expect-errors"), f"bad mode {expect!r}"
    with open(path) as fh:
        doc = json.load(fh)
    assert doc["schema"] == "invertnet-lint/v2", doc.get("schema")
    nets = doc["networks"]
    assert nets, "lint report covers no networks"
    for n in nets:
        for key in ("name", "ok", "diagnostics", "peaks", "cost"):
            assert key in n, f"network entry missing {key!r}: {n}"
    if expect == "expect-errors":
        assert doc["errors"] > 0, "malformed manifest produced no errors"
        diags = [d for n in nets for d in n["diagnostics"]]
        assert diags, "errors counted but no diagnostics recorded"
        for d in diags:
            assert d["severity"] in ("error", "warning"), d
            assert d["code"] and d["message"], d
    else:
        assert doc["errors"] == 0, f"catalog lint found errors: {doc}"
        assert all(n["ok"] for n in nets), nets
        # clean networks must carry the v2 cost block: positive train
        # flops per schedule, stored cheapest, invertible costliest
        for n in nets:
            cost = n["cost"]
            assert cost, f"clean network {n['name']} has no cost block"
            train = cost["train"]
            assert set(train) == {"invertible", "stored",
                                  "checkpoint_every_4"}, train
            for label, t in train.items():
                assert t["flops"] > 0 and t["bytes"] > 0, (label, t)
            assert train["stored"]["flops"] <= \
                train["checkpoint_every_4"]["flops"] <= \
                train["invertible"]["flops"], train
            assert 0 < cost["inference_flops"] < \
                train["stored"]["flops"], cost
            assert cost["sample_flops"] > 0, cost


CHECKS = {"serve": check_serve, "posterior": check_posterior,
          "bench": check_bench, "lint": check_lint,
          "metrics": check_metrics}


def main(argv):
    ok_arity = len(argv) == 3 or (len(argv) == 4 and argv[1] == "lint")
    if not ok_arity or argv[1] not in CHECKS:
        sys.stderr.write(__doc__)
        return 2
    CHECKS[argv[1]](*argv[2:])
    print(f"ci_smoke {argv[1]}: {argv[2]} ok")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
