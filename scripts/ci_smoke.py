#!/usr/bin/env python3
"""Validate the JSON-lines transcripts the CI smoke steps capture.

Usage:
    python3 scripts/ci_smoke.py serve     /tmp/serve_out.jsonl
    python3 scripts/ci_smoke.py posterior /tmp/post_serve.jsonl
    python3 scripts/ci_smoke.py bench     BENCH_quick.json
    python3 scripts/ci_smoke.py lint      /tmp/lint_catalog.json
    python3 scripts/ci_smoke.py lint      /tmp/lint_bad.json expect-errors

Each suite checks one kind of artifact:

* ``serve``     — a stdio serve session transcript: sample + score +
                  stats + shutdown, all ok, with the expected shapes.
* ``posterior`` — a posterior-op serve transcript: one posterior reply
                  (mean/std/samples) + shutdown.
* ``bench``     — a ``BENCH_<suite>.json`` document: schema tag, the
                  environment block, and at least one gated metric.
* ``lint``      — an ``invertnet lint --json`` report: schema tag and
                  per-network diagnostics. The default expects a clean
                  catalog; pass ``expect-errors`` as a third argument to
                  assert the report carries machine-readable diagnostics
                  (the malformed-manifest smoke).

Exit code 0 on success; an AssertionError message names what broke.
(Replaces the inline ``python3 -c`` heredocs that used to live in
.github/workflows/ci.yml — a checked-in script is diffable, lintable,
and shared between the smoke steps.)
"""

import json
import sys


def load_lines(path):
    with open(path) as fh:
        return [json.loads(line) for line in fh if line.strip()]


def check_serve(path):
    resp = load_lines(path)
    assert len(resp) == 4, f"expected 4 replies, got {len(resp)}: {resp}"
    assert all(r["ok"] for r in resp), resp
    assert resp[0]["x"]["shape"] == [2, 2], resp[0]
    assert len(resp[1]["log_density"]) == 2, resp[1]
    assert resp[2]["stats"]["requests"] == 2, resp[2]


def check_posterior(path):
    resp = load_lines(path)
    assert len(resp) == 2, f"expected 2 replies, got {len(resp)}: {resp}"
    assert all(r["ok"] for r in resp), resp
    post = resp[0]
    assert post["n"] == 32, post
    assert len(post["mean"]) == 2 and len(post["std"]) == 2, post
    assert all(s > 0 for s in post["std"]), post
    assert post["x"]["shape"] == [32, 2], post


def check_bench(path):
    with open(path) as fh:
        doc = json.load(fh)
    assert doc["schema"] == "invertnet-bench/v1", doc.get("schema")
    env = doc["env"]
    for key in ("git_rev", "threads", "cpus", "profile", "backend"):
        assert key in env, f"env block missing {key!r}: {env}"
    metrics = doc["metrics"]
    assert metrics, "no metrics recorded"
    gated = [m for m in metrics if m["check"]]
    assert gated, "no gated metrics — the regression gate would be empty"
    for m in metrics:
        assert isinstance(m["value"], (int, float)), m


def check_lint(path, expect="clean"):
    assert expect in ("clean", "expect-errors"), f"bad mode {expect!r}"
    with open(path) as fh:
        doc = json.load(fh)
    assert doc["schema"] == "invertnet-lint/v2", doc.get("schema")
    nets = doc["networks"]
    assert nets, "lint report covers no networks"
    for n in nets:
        for key in ("name", "ok", "diagnostics", "peaks", "cost"):
            assert key in n, f"network entry missing {key!r}: {n}"
    if expect == "expect-errors":
        assert doc["errors"] > 0, "malformed manifest produced no errors"
        diags = [d for n in nets for d in n["diagnostics"]]
        assert diags, "errors counted but no diagnostics recorded"
        for d in diags:
            assert d["severity"] in ("error", "warning"), d
            assert d["code"] and d["message"], d
    else:
        assert doc["errors"] == 0, f"catalog lint found errors: {doc}"
        assert all(n["ok"] for n in nets), nets
        # clean networks must carry the v2 cost block: positive train
        # flops per schedule, stored cheapest, invertible costliest
        for n in nets:
            cost = n["cost"]
            assert cost, f"clean network {n['name']} has no cost block"
            train = cost["train"]
            assert set(train) == {"invertible", "stored",
                                  "checkpoint_every_4"}, train
            for label, t in train.items():
                assert t["flops"] > 0 and t["bytes"] > 0, (label, t)
            assert train["stored"]["flops"] <= \
                train["checkpoint_every_4"]["flops"] <= \
                train["invertible"]["flops"], train
            assert 0 < cost["inference_flops"] < \
                train["stored"]["flops"], cost
            assert cost["sample_flops"] > 0, cost


CHECKS = {"serve": check_serve, "posterior": check_posterior,
          "bench": check_bench, "lint": check_lint}


def main(argv):
    ok_arity = len(argv) == 3 or (len(argv) == 4 and argv[1] == "lint")
    if not ok_arity or argv[1] not in CHECKS:
        sys.stderr.write(__doc__)
        return 2
    CHECKS[argv[1]](*argv[2:])
    print(f"ci_smoke {argv[1]}: {argv[2]} ok")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
